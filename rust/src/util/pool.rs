//! Scoped-thread worker pool for node-partitioned round execution.
//!
//! The DFL engines run the same three per-node phases every round
//! (quantized-delta broadcast, τ local-SGD steps, mixing); this pool
//! partitions the node slice into `workers` contiguous chunks and runs one
//! scoped thread per chunk. Design rules that keep the parallel path
//! *bit-identical* to the sequential one:
//!
//! * **Node partitioning, not work stealing** — every item is processed by
//!   exactly one worker, in index order within its chunk, so all per-item
//!   state (RNG streams, quantizer warm starts) sees the same operation
//!   sequence regardless of worker count.
//! * **No cross-item reduction inside the pool** — workers only write
//!   per-item outputs; callers reduce them sequentially in index order
//!   afterwards, so floating-point accumulation order never changes.
//! * `workers == 1` (or a single item) short-circuits to a plain loop on
//!   the calling thread: the sequential engine *is* the parallel engine
//!   with one worker.
//!
//! Errors: the first `Err` in chunk order is returned. A panicking worker
//! re-raises the panic on the calling thread (so test assertions inside
//! closures behave as usual).

use crate::config::Parallelism;

/// A small fork-join executor over mutable slices.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn new(workers: usize) -> Self {
        WorkerPool { workers: workers.max(1) }
    }

    /// Pool sized by the config knob for `items` work items:
    /// `auto` = available hardware parallelism, `off` = 1, `N` = N —
    /// always clamped to `items`.
    pub fn from_parallelism(p: Parallelism, items: usize) -> Self {
        WorkerPool::new(p.workers(items))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// True when this pool executes on the calling thread only.
    pub fn is_sequential(&self) -> bool {
        self.workers == 1
    }

    /// Contiguous chunk sizes for `len` items over `w` workers (first
    /// `len % w` chunks get one extra item).
    fn chunk_sizes(len: usize, w: usize) -> Vec<usize> {
        let base = len / w;
        let rem = len % w;
        (0..w).map(|ci| base + usize::from(ci < rem)).collect()
    }

    /// Run `f(index, &mut items[index])` for every index, partitioned
    /// across the pool. See module docs for the determinism contract.
    pub fn run<T, F>(&self, items: &mut [T], f: F) -> anyhow::Result<()>
    where
        T: Send,
        F: Fn(usize, &mut T) -> anyhow::Result<()> + Sync,
    {
        // delegate to the two-slice core with a zero-sized companion slice
        // (Vec<()> never allocates), so both entry points share one
        // spawn/join/error implementation
        let mut unit: Vec<()> = vec![(); items.len()];
        self.run2(items, &mut unit, |i, item, _| f(i, item))
    }

    /// As [`run`](WorkerPool::run) over two equally partitioned slices:
    /// `f(index, &mut a[index], &mut b[index])`. Used where per-node state
    /// lives in two parallel vectors (node states + compute backends).
    pub fn run2<A, B, F>(
        &self,
        a: &mut [A],
        b: &mut [B],
        f: F,
    ) -> anyhow::Result<()>
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) -> anyhow::Result<()> + Sync,
    {
        assert_eq!(a.len(), b.len(), "run2 slices must be equal length");
        let w = self.workers.min(a.len());
        if w <= 1 {
            for (i, (ai, bi)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, ai, bi)?;
            }
            return Ok(());
        }
        let sizes = Self::chunk_sizes(a.len(), w);
        let mut results: Vec<anyhow::Result<()>> = Vec::with_capacity(w);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(w);
            let mut rest_a = a;
            let mut rest_b = b;
            let mut start = 0usize;
            for &take in &sizes {
                let (chunk_a, tail_a) = rest_a.split_at_mut(take);
                let (chunk_b, tail_b) = rest_b.split_at_mut(take);
                rest_a = tail_a;
                rest_b = tail_b;
                let fr = &f;
                handles.push(scope.spawn(move || -> anyhow::Result<()> {
                    for (off, (ai, bi)) in
                        chunk_a.iter_mut().zip(chunk_b.iter_mut()).enumerate()
                    {
                        fr(start + off, ai, bi)?;
                    }
                    Ok(())
                }));
                start += take;
            }
            for h in handles {
                match h.join() {
                    Ok(r) => results.push(r),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        for r in results {
            r?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_sizes_cover_everything() {
        for len in [0usize, 1, 2, 5, 16, 33] {
            for w in [1usize, 2, 3, 8] {
                let sizes = WorkerPool::chunk_sizes(len, w);
                assert_eq!(sizes.len(), w);
                assert_eq!(sizes.iter().sum::<usize>(), len);
                // balanced within one item
                let mx = sizes.iter().max().unwrap();
                let mn = sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "len={len} w={w}: {sizes:?}");
            }
        }
    }

    #[test]
    fn run_visits_every_index_once() {
        for workers in [1usize, 2, 3, 7] {
            let pool = WorkerPool::new(workers);
            let mut items: Vec<usize> = vec![0; 23];
            pool.run(&mut items, |i, slot| {
                *slot += i + 1;
                Ok(())
            })
            .unwrap();
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i + 1);
            }
        }
    }

    #[test]
    fn run2_keeps_slices_aligned() {
        let pool = WorkerPool::new(4);
        let mut a: Vec<usize> = (0..17).collect();
        let mut b: Vec<usize> = vec![0; 17];
        pool.run2(&mut a, &mut b, |i, ai, bi| {
            assert_eq!(*ai, i);
            *bi = *ai * 2;
            Ok(())
        })
        .unwrap();
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }

    #[test]
    fn first_error_in_index_order_wins() {
        let pool = WorkerPool::new(4);
        let mut items = vec![0u8; 16];
        let err = pool
            .run(&mut items, |i, _| {
                if i >= 3 {
                    anyhow::bail!("failed at {i}");
                }
                Ok(())
            })
            .unwrap_err();
        // chunk 0 holds indices 0..4 and fails first at 3; later chunks
        // also fail, but chunk order must report the earliest chunk's error
        assert_eq!(err.to_string(), "failed at 3");
    }

    #[test]
    fn parallel_workers_actually_run() {
        let pool = WorkerPool::new(2);
        assert!(!pool.is_sequential());
        let count = AtomicUsize::new(0);
        let mut items = vec![(); 8];
        pool.run(&mut items, |_, _| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn from_parallelism_clamps() {
        assert!(WorkerPool::from_parallelism(Parallelism::Off, 64)
            .is_sequential());
        assert_eq!(
            WorkerPool::from_parallelism(Parallelism::Fixed(8), 3).workers(),
            3
        );
        assert!(WorkerPool::from_parallelism(Parallelism::Auto, 64)
            .workers() >= 1);
    }

    #[test]
    fn empty_slice_is_a_no_op() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = Vec::new();
        pool.run(&mut items, |_, _| anyhow::bail!("never called"))
            .unwrap();
    }
}
