//! Persistent parked worker pool for node-partitioned round execution.
//!
//! The DFL engines run the same three per-node phases every round
//! (quantized-delta broadcast, τ local-SGD steps, mixing) plus the
//! sharded eval. Historically each phase forked and joined a fresh set
//! of scoped threads (3+ spawns per round); this pool instead spawns its
//! workers **once** (per `DflEngine` / `Trainer`), parks them on a
//! condvar between phases, and wakes them per job — per-round overhead
//! is a mutex hand-off instead of thread creation. Design rules that
//! keep the parallel path *bit-identical* to the sequential one are
//! unchanged from the scoped-thread pool:
//!
//! * **Node partitioning, not work stealing** — every item is processed
//!   by exactly one worker, in index order within its contiguous chunk,
//!   so all per-item state (RNG streams, quantizer warm starts) sees the
//!   same operation sequence regardless of worker count.
//! * **No cross-item reduction inside the pool** — workers only write
//!   per-item outputs; callers reduce them sequentially in index order
//!   afterwards, so floating-point accumulation order never changes.
//! * `workers == 1` (or a single item) short-circuits to a plain loop on
//!   the calling thread — a sequential pool owns **no threads at all**.
//!
//! Chunk 0 of every job runs on the submitting thread itself (one fewer
//! wakeup; the submitter would otherwise just block), chunks 1..w on the
//! parked workers — the chunk→thread mapping is fixed, so per-chunk
//! cache locality carries across rounds.
//!
//! Errors: the first `Err` in chunk order is returned. A panicking chunk
//! re-raises its payload on the calling thread (earliest chunk wins),
//! and the pool remains serviceable afterwards. Jobs must not submit
//! nested jobs to the same pool (the engines never do).

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::config::Parallelism;

/// Fat pointer to the current job's per-chunk closure, lifetime-erased.
/// Only valid while the submitting `run_job` call blocks: workers never
/// touch it after decrementing `active`, and `run_job` does not return
/// until `active == 0`.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (callable from any thread through a
// shared reference) and outlives every use — the submitting thread keeps
// the closure alive for the whole job (see `JobPtr` docs).
unsafe impl Send for JobPtr {}

struct PoolState {
    /// chunk closure of the in-flight job (`None` between jobs)
    job: Option<JobPtr>,
    /// bumped once per job so parked workers recognize new work
    epoch: u64,
    /// chunk count of the current job (worker `w` runs chunk `w + 1`
    /// when `w + 1 < width`)
    width: usize,
    /// participating workers still running the current job
    active: usize,
    /// worker panics as (chunk index, payload); resolved in chunk order
    panics: Vec<(usize, Box<dyn Any + Send>)>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// workers park here waiting for a new epoch
    work: Condvar,
    /// the submitting thread parks here waiting for `active == 0`
    done: Condvar,
}

fn worker_loop(shared: &Shared, wi: usize) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    last_epoch = st.epoch;
                    if wi + 1 < st.width {
                        break st.job.expect("job set for new epoch");
                    }
                    // narrower job than the pool: not our chunk
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let chunk = wi + 1;
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: the submitter keeps the closure alive until every
            // participating worker has decremented `active` (below)
            let f = unsafe { &*job.0 };
            f(chunk)
        }));
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            st.panics.push((chunk, payload));
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Raw slice base pointer smuggled into the shared chunk closure.
struct SendSlice<T>(*mut T);

// SAFETY: workers only ever form &mut chunks over *disjoint* index
// ranges (one chunk per worker per job, synchronized by the job
// protocol); `T: Send` on the entry points keeps the cross-thread
// access legal.
unsafe impl<T: Send> Sync for SendSlice<T> {}

/// A persistent fork-join executor over mutable slices.
pub struct WorkerPool {
    workers: usize,
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool with an explicit worker count (clamped to >= 1). Spawns
    /// `workers - 1` parked OS threads once — job submission never
    /// spawns.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                width: 0,
                active: 0,
                panics: Vec::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers - 1)
            .map(|wi| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lmdfl-pool-{wi}"))
                    .spawn(move || worker_loop(&shared, wi))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { workers, shared, handles }
    }

    /// Pool sized by the config knob for `items` work items:
    /// `auto` = available hardware parallelism, `off` = 1, `N` = N —
    /// always clamped to `items`.
    pub fn from_parallelism(p: Parallelism, items: usize) -> Self {
        WorkerPool::new(p.workers(items))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// True when this pool executes on the calling thread only.
    pub fn is_sequential(&self) -> bool {
        self.workers == 1
    }

    /// Contiguous chunk sizes for `len` items over `w` workers (first
    /// `len % w` chunks get one extra item).
    fn chunk_sizes(len: usize, w: usize) -> Vec<usize> {
        let base = len / w;
        let rem = len % w;
        (0..w).map(|ci| base + usize::from(ci < rem)).collect()
    }

    /// Submit one job of `width >= 2` chunks: wake the parked workers
    /// for chunks 1..width, run chunk 0 inline, wait for completion, and
    /// re-raise the earliest chunk's panic (if any).
    fn run_job(&self, width: usize, f: &(dyn Fn(usize) + Sync)) {
        debug_assert!(width >= 2);
        debug_assert!(width - 1 <= self.handles.len());
        let job = {
            // SAFETY: lifetime erasure only — this function blocks until
            // every worker is done with the closure (wait loop below),
            // so the borrow outlives all use
            let f: &'static (dyn Fn(usize) + Sync + 'static) =
                unsafe { std::mem::transmute(f) };
            JobPtr(f as *const _)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none() && st.active == 0);
            st.job = Some(job);
            st.width = width;
            st.active = width - 1;
            st.epoch = st.epoch.wrapping_add(1);
        }
        self.shared.work.notify_all();

        // chunk 0 runs on the submitting thread
        let own = catch_unwind(AssertUnwindSafe(|| f(0)));

        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        let mut panics = std::mem::take(&mut st.panics);
        drop(st);
        if let Err(payload) = own {
            panics.push((0, payload));
        }
        if !panics.is_empty() {
            panics.sort_by_key(|(chunk, _)| *chunk);
            let (_, payload) = panics.swap_remove(0);
            resume_unwind(payload);
        }
    }

    /// Run `f(index, &mut items[index])` for every index, partitioned
    /// across the pool. See module docs for the determinism contract.
    pub fn run<T, F>(&self, items: &mut [T], f: F) -> anyhow::Result<()>
    where
        T: Send,
        F: Fn(usize, &mut T) -> anyhow::Result<()> + Sync,
    {
        // delegate to the two-slice core with a zero-sized companion slice
        // (Vec<()> never allocates), so both entry points share one
        // submission/error implementation
        let mut unit: Vec<()> = vec![(); items.len()];
        self.run2(items, &mut unit, |i, item, _| f(i, item))
    }

    /// As [`run`](WorkerPool::run) over two equally partitioned slices:
    /// `f(index, &mut a[index], &mut b[index])`. Used where per-node state
    /// lives in two parallel vectors (node states + compute backends).
    pub fn run2<A, B, F>(
        &self,
        a: &mut [A],
        b: &mut [B],
        f: F,
    ) -> anyhow::Result<()>
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) -> anyhow::Result<()> + Sync,
    {
        assert_eq!(a.len(), b.len(), "run2 slices must be equal length");
        let w = self.workers.min(a.len());
        if w <= 1 {
            for (i, (ai, bi)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, ai, bi)?;
            }
            return Ok(());
        }
        let sizes = Self::chunk_sizes(a.len(), w);
        let mut bounds = Vec::with_capacity(w);
        let mut start = 0usize;
        for &take in &sizes {
            bounds.push((start, start + take));
            start += take;
        }
        let errors: Vec<Mutex<Option<anyhow::Error>>> =
            (0..w).map(|_| Mutex::new(None)).collect();
        let a_ptr = SendSlice(a.as_mut_ptr());
        let b_ptr = SendSlice(b.as_mut_ptr());
        let bounds = &bounds;
        let errors_ref = &errors;
        let fr = &f;
        let chunk_fn = move |ci: usize| {
            let (s, e) = bounds[ci];
            // SAFETY: chunk index ranges are disjoint and each chunk is
            // executed by exactly one thread per job, so these &mut
            // sub-slices never alias
            let ca = unsafe {
                std::slice::from_raw_parts_mut(a_ptr.0.add(s), e - s)
            };
            let cb = unsafe {
                std::slice::from_raw_parts_mut(b_ptr.0.add(s), e - s)
            };
            for (off, (ai, bi)) in
                ca.iter_mut().zip(cb.iter_mut()).enumerate()
            {
                if let Err(err) = fr(s + off, ai, bi) {
                    // first error stops this chunk, like the scoped
                    // pool's `?` did
                    *errors_ref[ci].lock().unwrap() = Some(err);
                    return;
                }
            }
        };
        self.run_job(w, &chunk_fn);
        for slot in errors {
            if let Some(err) = slot.into_inner().unwrap() {
                return Err(err);
            }
        }
        Ok(())
    }
}

impl Clone for WorkerPool {
    /// A clone is a fresh pool of the same width — parked threads are
    /// never shared between pools.
    fn clone(&self) -> Self {
        WorkerPool::new(self.workers)
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("parked_threads", &self.handles.len())
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_sizes_cover_everything() {
        for len in [0usize, 1, 2, 5, 16, 33] {
            for w in [1usize, 2, 3, 8] {
                let sizes = WorkerPool::chunk_sizes(len, w);
                assert_eq!(sizes.len(), w);
                assert_eq!(sizes.iter().sum::<usize>(), len);
                // balanced within one item
                let mx = sizes.iter().max().unwrap();
                let mn = sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "len={len} w={w}: {sizes:?}");
            }
        }
    }

    #[test]
    fn run_visits_every_index_once() {
        for workers in [1usize, 2, 3, 7] {
            let pool = WorkerPool::new(workers);
            let mut items: Vec<usize> = vec![0; 23];
            pool.run(&mut items, |i, slot| {
                *slot += i + 1;
                Ok(())
            })
            .unwrap();
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i + 1);
            }
        }
    }

    #[test]
    fn run2_keeps_slices_aligned() {
        let pool = WorkerPool::new(4);
        let mut a: Vec<usize> = (0..17).collect();
        let mut b: Vec<usize> = vec![0; 17];
        pool.run2(&mut a, &mut b, |i, ai, bi| {
            assert_eq!(*ai, i);
            *bi = *ai * 2;
            Ok(())
        })
        .unwrap();
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }

    #[test]
    fn first_error_in_index_order_wins() {
        let pool = WorkerPool::new(4);
        let mut items = vec![0u8; 16];
        let err = pool
            .run(&mut items, |i, _| {
                if i >= 3 {
                    anyhow::bail!("failed at {i}");
                }
                Ok(())
            })
            .unwrap_err();
        // chunk 0 holds indices 0..4 and fails first at 3; later chunks
        // also fail, but chunk order must report the earliest chunk's error
        assert_eq!(err.to_string(), "failed at 3");
    }

    #[test]
    fn parallel_workers_actually_run() {
        let pool = WorkerPool::new(2);
        assert!(!pool.is_sequential());
        let count = AtomicUsize::new(0);
        let mut items = vec![(); 8];
        pool.run(&mut items, |_, _| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn from_parallelism_clamps() {
        assert!(WorkerPool::from_parallelism(Parallelism::Off, 64)
            .is_sequential());
        assert_eq!(
            WorkerPool::from_parallelism(Parallelism::Fixed(8), 3).workers(),
            3
        );
        assert!(WorkerPool::from_parallelism(Parallelism::Auto, 64)
            .workers() >= 1);
    }

    #[test]
    fn empty_slice_is_a_no_op() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = Vec::new();
        pool.run(&mut items, |_, _| anyhow::bail!("never called"))
            .unwrap();
    }

    #[test]
    fn sequential_pool_owns_no_threads() {
        let pool = WorkerPool::new(1);
        assert!(pool.is_sequential());
        assert!(pool.handles.is_empty());
        let mut items = vec![0usize; 4];
        pool.run(&mut items, |i, slot| {
            *slot = i;
            Ok(())
        })
        .unwrap();
        assert_eq!(items, vec![0, 1, 2, 3]);
    }

    #[test]
    fn workers_persist_across_jobs() {
        // the per-round phases must reuse the same parked threads: the
        // chunk -> thread-id mapping is stable across many jobs
        let pool = WorkerPool::new(4);
        let ids = |pool: &WorkerPool| -> Vec<std::thread::ThreadId> {
            let mut slots: Vec<Option<std::thread::ThreadId>> =
                vec![None; 8];
            pool.run(&mut slots, |_, slot| {
                *slot = Some(std::thread::current().id());
                Ok(())
            })
            .unwrap();
            slots.into_iter().map(|s| s.unwrap()).collect()
        };
        let first = ids(&pool);
        for round in 0..20 {
            let again = ids(&pool);
            assert_eq!(first, again, "thread mapping moved at {round}");
        }
        // chunk 0 runs inline on the submitting thread
        assert_eq!(first[0], std::thread::current().id());
        // 8 items over 4 workers -> 4 chunks on 4 distinct threads
        let distinct: HashSet<_> = first.iter().cloned().collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn panic_in_worker_propagates_and_pool_survives() {
        let pool = WorkerPool::new(3);
        let mut items = vec![0u32; 9];
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut items, |i, _| {
                if i == 7 {
                    panic!("boom at {i}");
                }
                Ok(())
            })
        }));
        assert!(result.is_err(), "worker panic must re-raise");
        // the pool stays serviceable after a panic
        let mut items = vec![0u32; 9];
        pool.run(&mut items, |i, slot| {
            *slot = i as u32;
            Ok(())
        })
        .unwrap();
        assert_eq!(items[8], 8);
    }

    #[test]
    fn earliest_chunk_panic_wins() {
        // scoped-pool parity: panics resolve in chunk order (and take
        // precedence over later Err returns)
        let pool = WorkerPool::new(4);
        let mut items = vec![0u8; 8];
        let payload = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut items, |i, _| {
                if i >= 2 {
                    panic!("chunk payload {}", i / 2);
                }
                Ok(())
            })
        }))
        .unwrap_err();
        let msg = payload.downcast_ref::<String>().unwrap();
        assert_eq!(msg, "chunk payload 1");
    }

    #[test]
    fn panic_beats_error_like_scoped_join_order_did() {
        // old pool: join in chunk order resumed the first panic even if
        // an earlier-indexed chunk had returned Err
        let pool = WorkerPool::new(2);
        let mut items = vec![0u8; 4];
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut items, |i, _| {
                if i < 2 {
                    anyhow::bail!("error in chunk 0");
                }
                panic!("panic in chunk 1");
            })
        }));
        assert!(result.is_err(), "the panic must win over the error");
    }

    #[test]
    fn errors_from_many_rounds_reported_independently() {
        // reuse across "rounds": failures in one job don't leak into the
        // next (state fully resets between jobs)
        let pool = WorkerPool::new(3);
        for round in 0..10 {
            let mut items = vec![0usize; 9];
            let res = pool.run(&mut items, |i, slot| {
                if round % 2 == 0 && i == 4 {
                    anyhow::bail!("round {round} item {i}");
                }
                *slot = i;
                Ok(())
            });
            if round % 2 == 0 {
                let msg = res.unwrap_err().to_string();
                assert_eq!(msg, format!("round {round} item 4"));
            } else {
                res.unwrap();
                assert_eq!(items[8], 8);
            }
        }
    }
}
