//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `SplitMix64` for seeding, `Xoshiro256StarStar` as the workhorse
//! generator (Blackman & Vigna). Adds the sampling helpers the simulator
//! needs: uniforms, normals (Box–Muller), integer ranges, shuffles,
//! categorical choice.

/// splitmix64 step — used to expand a single `u64` seed into a full
/// xoshiro state and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically; any u64 is fine (0 included).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-node RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-ish rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for simulation use
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(mean, std) f32s.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with uniform f32s in [0, 1) — one
    /// [`uniform_f32`](Self::uniform_f32)-equivalent draw per element in
    /// element order. The batched quantizer kernels pre-draw their
    /// stochastic-rounding uniforms with this so the draw sequence stays
    /// bit-identical to the per-element loops.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// Laplace(0, b) sample — used by the distortion benches: gradient
    /// coordinates are famously heavier-tailed than Gaussian.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `k` distinct indices out of `n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let b = 0.7;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.laplace(b);
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02);
        assert!((v - 2.0 * b * b).abs() < 0.1, "var={v}");
    }

    #[test]
    fn fill_uniform_matches_per_element_draws() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        let mut buf = vec![0.0f32; 64];
        a.fill_uniform_f32(&mut buf);
        for &x in &buf {
            assert_eq!(x.to_bits(), b.uniform_f32().to_bits());
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn choice_weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.choice_weighted(&w)] += 1;
        }
        assert!(counts[1] > 4000, "{counts:?}");
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut root = Rng::new(1234);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
