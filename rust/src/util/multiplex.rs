//! Node-group multiplexing: many logical nodes per persistent pool
//! worker, with envelope-addressed shared mailboxes.
//!
//! At 10k nodes, "one execution unit per node" stops being a sensible
//! model — 10k OS threads don't fit, and even 10k pool work items make
//! the dispatch bookkeeping O(n). [`NodeGroups`] partitions the node
//! index space into contiguous bounded groups and dispatches *groups*
//! as the work items of a [`WorkerPool`] job: more groups than workers
//! means each persistent worker services several groups per phase
//! (multiplexing), while nodes inside a group always run in ascending
//! index order on a single thread. Both properties preserve the
//! engines' bit-identity contract — per-node work is independent, the
//! order within a group is fixed, and cross-node reductions stay
//! sequential in node order (see [`crate::util::pool`] module docs).
//!
//! [`GroupMailboxes`] is the companion delivery structure: one shared
//! mailbox per *group* (not per node), addressed by [`Envelope`]s.
//! Posting locks only the destination node's group box; draining a
//! group sorts its envelopes by `(to, from)`, so a consumer that
//! drains groups in index order observes one canonical global order
//! no matter which worker posted first. The sync engine routes every
//! node's per-round outputs through these boxes
//! ([`crate::dfl::DflEngine`]), so 10k node state machines cost
//! O(groups) queues, not O(n).

use std::sync::Mutex;

use super::pool::WorkerPool;

/// Target nodes per group for engine-sized deployments: small enough
/// that groups balance across workers, large enough that per-group
/// dispatch overhead is negligible against per-node work.
pub const GROUP_NODES: usize = 64;

/// Raw slice base pointer smuggled into the per-group closure (the
/// [`crate::util::pool`] `SendSlice` pattern).
struct SendPtr<T>(*mut T);

// SAFETY: workers only ever form &mut sub-slices over *disjoint*
// group ranges (each group slot is processed by exactly one worker
// per job); `T: Send` on the entry points keeps cross-thread access
// legal.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// One dispatchable group: its node range plus an error stash the
/// driver resolves in group (= node) order after the job.
struct GroupSlot {
    start: usize,
    end: usize,
    err: Option<anyhow::Error>,
}

/// A contiguous partition of `0..n` into bounded node groups, usable
/// as the dispatch unit of a [`WorkerPool`] job.
pub struct NodeGroups {
    n: usize,
    /// even-partition shape: the first `rem` groups hold `base + 1`
    /// nodes, the rest `base` (same rule as the pool's chunk sizes)
    base: usize,
    rem: usize,
    /// reusable dispatch slots, one per group
    slots: Vec<GroupSlot>,
}

impl NodeGroups {
    /// Partition `n` nodes into exactly `groups` near-equal contiguous
    /// groups (clamped to `1..=max(n, 1)`).
    pub fn new(n: usize, groups: usize) -> Self {
        let groups = groups.clamp(1, n.max(1));
        let base = n / groups;
        let rem = n % groups;
        let mut slots = Vec::with_capacity(groups);
        let mut start = 0usize;
        for g in 0..groups {
            let take = base + usize::from(g < rem);
            slots.push(GroupSlot { start, end: start + take, err: None });
            start += take;
        }
        debug_assert_eq!(start, n);
        NodeGroups { n, base, rem, slots }
    }

    /// Partition by a target group size (`ceil(n / size)` groups).
    pub fn with_group_size(n: usize, size: usize) -> Self {
        Self::new(n, n.div_ceil(size.max(1)))
    }

    /// Engine sizing: group size bounded by [`GROUP_NODES`], but never
    /// fewer groups than the pool has workers (small fleets keep full
    /// parallelism; large fleets multiplex many groups per worker).
    pub fn for_pool(n: usize, workers: usize) -> Self {
        Self::new(n, n.div_ceil(GROUP_NODES).max(workers.min(n)))
    }

    /// Node count covered by the partition.
    pub fn nodes(&self) -> usize {
        self.n
    }

    /// Number of groups.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Node range `[start, end)` of group `g`.
    pub fn bounds(&self, g: usize) -> (usize, usize) {
        (self.slots[g].start, self.slots[g].end)
    }

    /// Group holding `node` (O(1) from the even-partition shape).
    pub fn group_of(&self, node: usize) -> usize {
        assert!(node < self.n, "node {node} out of range {}", self.n);
        let cut = (self.base + 1) * self.rem;
        if node < cut {
            node / (self.base + 1)
        } else {
            self.rem + (node - cut) / self.base
        }
    }

    /// Run `f(index, &mut items[index])` for every node, groups
    /// dispatched across the pool (see module docs for the
    /// determinism contract).
    pub fn run<T, F>(
        &mut self,
        pool: &WorkerPool,
        items: &mut [T],
        f: F,
    ) -> anyhow::Result<()>
    where
        T: Send,
        F: Fn(usize, &mut T) -> anyhow::Result<()> + Sync,
    {
        // zero-sized companion slice (never allocates), mirroring
        // WorkerPool::run
        let mut unit: Vec<()> = vec![(); items.len()];
        self.run2(pool, items, &mut unit, |i, item, _| f(i, item))
    }

    /// As [`run`](NodeGroups::run) over two equally partitioned
    /// slices: `f(index, &mut a[index], &mut b[index])`.
    pub fn run2<A, B, F>(
        &mut self,
        pool: &WorkerPool,
        a: &mut [A],
        b: &mut [B],
        f: F,
    ) -> anyhow::Result<()>
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) -> anyhow::Result<()> + Sync,
    {
        assert_eq!(a.len(), self.n, "slice must cover every node");
        assert_eq!(b.len(), self.n, "slice must cover every node");
        if pool.is_sequential() || self.slots.len() <= 1 {
            for (i, (ai, bi)) in a.iter_mut().zip(b.iter_mut()).enumerate()
            {
                f(i, ai, bi)?;
            }
            return Ok(());
        }
        let a_ptr = SendPtr(a.as_mut_ptr());
        let b_ptr = SendPtr(b.as_mut_ptr());
        let fr = &f;
        pool.run(&mut self.slots, |_, slot| {
            slot.err = None;
            let (s, e) = (slot.start, slot.end);
            // SAFETY: group node ranges are disjoint and each slot is
            // handed to exactly one worker per job, so these &mut
            // sub-slices never alias
            let ca = unsafe {
                std::slice::from_raw_parts_mut(a_ptr.0.add(s), e - s)
            };
            let cb = unsafe {
                std::slice::from_raw_parts_mut(b_ptr.0.add(s), e - s)
            };
            for (off, (ai, bi)) in
                ca.iter_mut().zip(cb.iter_mut()).enumerate()
            {
                if let Err(err) = fr(s + off, ai, bi) {
                    // first error stops this group; the driver below
                    // reports the earliest group's error, matching the
                    // pool's chunk-order semantics at group granularity
                    slot.err = Some(err);
                    return Ok(());
                }
            }
            Ok(())
        })?;
        for slot in &mut self.slots {
            if let Some(err) = slot.err.take() {
                return Err(err);
            }
        }
        Ok(())
    }
}

impl std::fmt::Debug for NodeGroups {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NodeGroups")
            .field("nodes", &self.n)
            .field("groups", &self.slots.len())
            .finish()
    }
}

/// One addressed message between nodes (or node → reducer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Envelope<M> {
    pub to: usize,
    pub from: usize,
    pub msg: M,
}

/// Envelope-addressed shared mailboxes, one per node *group*.
///
/// `post` routes by the destination node's group and takes that one
/// box's lock; `drain_group` empties a box (capacity retained) and
/// sorts the drained tail by `(to, from)`. Draining groups `0..len`
/// in order therefore yields every envelope in one canonical global
/// `(to, from)` order regardless of posting thread interleaving —
/// the determinism contract consumers rely on. Envelopes that share
/// `(to, from)` keep their posting order (stable sort).
pub struct GroupMailboxes<M> {
    /// node→group routing (the owning partition's shape)
    n: usize,
    base: usize,
    rem: usize,
    boxes: Vec<Mutex<Vec<Envelope<M>>>>,
}

impl<M> GroupMailboxes<M> {
    /// One empty mailbox per group of `groups`.
    pub fn new(groups: &NodeGroups) -> Self {
        GroupMailboxes {
            n: groups.n,
            base: groups.base,
            rem: groups.rem,
            boxes: (0..groups.len()).map(|_| Mutex::new(Vec::new()))
                .collect(),
        }
    }

    /// Number of group mailboxes.
    pub fn len(&self) -> usize {
        self.boxes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.boxes.is_empty()
    }

    fn group_of(&self, node: usize) -> usize {
        assert!(node < self.n, "node {node} out of range {}", self.n);
        let cut = (self.base + 1) * self.rem;
        if node < cut {
            node / (self.base + 1)
        } else {
            self.rem + (node - cut) / self.base
        }
    }

    /// Post into the destination node's group box.
    pub fn post(&self, env: Envelope<M>) {
        let g = self.group_of(env.to);
        self.boxes[g].lock().unwrap().push(env);
    }

    /// Convenience form of [`post`](GroupMailboxes::post).
    pub fn post_to(&self, to: usize, from: usize, msg: M) {
        self.post(Envelope { to, from, msg });
    }

    /// Total envelopes currently queued (tests / diagnostics).
    pub fn pending(&self) -> usize {
        self.boxes.iter().map(|b| b.lock().unwrap().len()).sum()
    }

    /// Move group `g`'s envelopes onto the end of `out` (the box keeps
    /// its capacity), then sort the appended tail by `(to, from)`.
    pub fn drain_group(&self, g: usize, out: &mut Vec<Envelope<M>>) {
        let start = out.len();
        {
            let mut bx = self.boxes[g].lock().unwrap();
            out.append(&mut bx);
        }
        out[start..].sort_by_key(|e| (e.to, e.from));
    }

    /// Drain every group in index order (the canonical global order).
    pub fn drain_all(&self, out: &mut Vec<Envelope<M>>) {
        for g in 0..self.boxes.len() {
            self.drain_group(g, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_everything_contiguously() {
        for n in [0usize, 1, 2, 7, 64, 65, 1000] {
            for groups in [1usize, 2, 3, 8, 200] {
                let ng = NodeGroups::new(n, groups);
                assert!(ng.len() >= 1);
                assert!(ng.len() <= n.max(1));
                let mut next = 0usize;
                for g in 0..ng.len() {
                    let (s, e) = ng.bounds(g);
                    assert_eq!(s, next, "gap at group {g} (n={n})");
                    assert!(e >= s);
                    next = e;
                }
                assert_eq!(next, n, "partition must cover 0..{n}");
            }
        }
    }

    #[test]
    fn group_sizes_are_balanced_and_bounded() {
        let ng = NodeGroups::with_group_size(1000, 64);
        assert_eq!(ng.len(), 16); // ceil(1000/64)
        for g in 0..ng.len() {
            let (s, e) = ng.bounds(g);
            assert!(e - s <= 64, "group {g} exceeds the size bound");
        }
        // balanced within one node
        let sizes: Vec<usize> =
            (0..ng.len()).map(|g| ng.bounds(g).1 - ng.bounds(g).0).collect();
        let mx = sizes.iter().max().unwrap();
        let mn = sizes.iter().min().unwrap();
        assert!(mx - mn <= 1, "{sizes:?}");
    }

    #[test]
    fn for_pool_multiplexes_large_and_spreads_small() {
        // large fleet: many more groups than workers (multiplexing),
        // group size bounded
        let big = NodeGroups::for_pool(10_000, 8);
        assert!(big.len() >= 10_000 / GROUP_NODES);
        for g in 0..big.len() {
            let (s, e) = big.bounds(g);
            assert!(e - s <= GROUP_NODES);
        }
        // small fleet: one group per worker, full parallelism
        let small = NodeGroups::for_pool(16, 8);
        assert_eq!(small.len(), 8);
        // tiny fleet: clamped to n
        assert_eq!(NodeGroups::for_pool(3, 8).len(), 3);
    }

    #[test]
    fn group_of_matches_bounds() {
        for (n, groups) in [(10, 3), (64, 8), (1000, 17), (7, 7)] {
            let ng = NodeGroups::new(n, groups);
            for g in 0..ng.len() {
                let (s, e) = ng.bounds(g);
                for node in s..e {
                    assert_eq!(ng.group_of(node), g, "n={n} node={node}");
                }
            }
        }
    }

    #[test]
    fn run_visits_every_node_once_any_worker_count() {
        for workers in [1usize, 2, 3, 7] {
            let pool = WorkerPool::new(workers);
            let mut ng = NodeGroups::new(23, 9);
            let mut items: Vec<usize> = vec![0; 23];
            ng.run(&pool, &mut items, |i, slot| {
                *slot += i + 1;
                Ok(())
            })
            .unwrap();
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i + 1, "workers={workers}");
            }
        }
    }

    #[test]
    fn run2_keeps_slices_aligned() {
        let pool = WorkerPool::new(4);
        let mut ng = NodeGroups::new(17, 6);
        let mut a: Vec<usize> = (0..17).collect();
        let mut b: Vec<usize> = vec![0; 17];
        ng.run2(&pool, &mut a, &mut b, |i, ai, bi| {
            assert_eq!(*ai, i);
            *bi = *ai * 2;
            Ok(())
        })
        .unwrap();
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }

    #[test]
    fn more_groups_than_workers_all_run() {
        // 32 groups over 3 workers: every group executes (multiplexed)
        let pool = WorkerPool::new(3);
        let mut ng = NodeGroups::new(256, 32);
        assert_eq!(ng.len(), 32);
        let count = AtomicUsize::new(0);
        let mut items = vec![(); 256];
        ng.run(&pool, &mut items, |_, _| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 256);
    }

    #[test]
    fn earliest_group_error_wins() {
        let pool = WorkerPool::new(4);
        let mut ng = NodeGroups::new(16, 8);
        let mut items = vec![0u8; 16];
        let err = ng
            .run(&pool, &mut items, |i, _| {
                if i >= 3 {
                    anyhow::bail!("failed at {i}");
                }
                Ok(())
            })
            .unwrap_err();
        // groups of 2: group 1 fails first at node 3; later groups
        // also fail but group order must report the earliest
        assert_eq!(err.to_string(), "failed at 3");
    }

    #[test]
    fn sequential_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let mut ng = NodeGroups::new(8, 4);
        let mut items = vec![0usize; 8];
        ng.run(&pool, &mut items, |i, slot| {
            *slot = i;
            Ok(())
        })
        .unwrap();
        assert_eq!(items, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn mailboxes_route_by_destination_group() {
        let ng = NodeGroups::new(100, 10);
        let mb: GroupMailboxes<u64> = GroupMailboxes::new(&ng);
        assert_eq!(mb.len(), 10);
        mb.post_to(5, 99, 500);
        mb.post_to(95, 0, 9500);
        assert_eq!(mb.pending(), 2);
        let mut out = Vec::new();
        mb.drain_group(ng.group_of(5), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], Envelope { to: 5, from: 99, msg: 500 });
        out.clear();
        mb.drain_group(ng.group_of(95), &mut out);
        assert_eq!(out[0].msg, 9500);
        assert_eq!(mb.pending(), 0);
    }

    #[test]
    fn drain_order_is_canonical_regardless_of_post_order() {
        let ng = NodeGroups::new(12, 3);
        let mb: GroupMailboxes<&'static str> = GroupMailboxes::new(&ng);
        // post in a scrambled order, from scrambled senders
        mb.post_to(11, 3, "k");
        mb.post_to(0, 9, "b");
        mb.post_to(7, 1, "f");
        mb.post_to(0, 2, "a");
        mb.post_to(7, 4, "g");
        mb.post_to(3, 0, "c");
        let mut out = Vec::new();
        mb.drain_all(&mut out);
        let keys: Vec<(usize, usize)> =
            out.iter().map(|e| (e.to, e.from)).collect();
        assert_eq!(
            keys,
            vec![(0, 2), (0, 9), (3, 0), (7, 1), (7, 4), (11, 3)],
            "global (to, from) order"
        );
        let msgs: Vec<&str> = out.iter().map(|e| e.msg).collect();
        assert_eq!(msgs, vec!["a", "b", "c", "f", "g", "k"]);
    }

    #[test]
    fn concurrent_posts_drain_deterministically() {
        // many workers post through the group run; the drained order
        // must be the canonical one for any worker count
        let expect: Vec<(usize, usize)> =
            (0..64).map(|i| (63 - i, i)).collect();
        let mut orders = Vec::new();
        for workers in [1usize, 3, 8] {
            let pool = WorkerPool::new(workers);
            let mut ng = NodeGroups::new(64, 16);
            let mb: GroupMailboxes<usize> = GroupMailboxes::new(&ng);
            let mut items = vec![(); 64];
            ng.run(&pool, &mut items, |i, _| {
                // cross-group traffic: node i writes to node 63−i
                mb.post_to(63 - i, i, i * 10);
                Ok(())
            })
            .unwrap();
            let mut out = Vec::new();
            mb.drain_all(&mut out);
            let keys: Vec<(usize, usize)> =
                out.iter().map(|e| (e.to, e.from)).collect();
            let mut sorted = expect.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted, "workers={workers}");
            orders.push(out.iter().map(|e| e.msg).collect::<Vec<_>>());
        }
        assert!(orders.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn drain_retains_box_capacity() {
        let ng = NodeGroups::new(8, 2);
        let mb: GroupMailboxes<u32> = GroupMailboxes::new(&ng);
        let mut out = Vec::new();
        for _ in 0..3 {
            for i in 0..8 {
                mb.post_to(i, i, i as u32);
            }
            out.clear();
            mb.drain_all(&mut out);
            assert_eq!(out.len(), 8);
        }
    }
}
