//! Utility substrates: PRNG, statistics, property-test harness, timing,
//! and the scoped-thread worker pool behind the parallel round executor.

pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
