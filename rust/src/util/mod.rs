//! Utility substrates: PRNG, statistics, property-test harness, timing,
//! the persistent worker pool behind the parallel round executor, and
//! the node-group multiplexer that scales it to 10k-node fleets.

pub mod multiplex;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
