//! Utility substrates: PRNG, statistics, property-test harness, timing.

pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
