//! Minimal property-testing harness (no `proptest` crate offline).
//!
//! A property is a closure over a seeded [`Gen`]; [`check`] runs it across
//! many derived seeds and reports the first failing seed so failures are
//! reproducible (`check_seeded` replays one case).
//!
//! ```
//! use lmdfl::util::proptest::{check, Gen};
//! check("reverse twice is identity", 64, |g: &mut Gen| {
//!     let xs = g.vec_f32(0..100, -1e3..1e3);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::rng::Rng;

/// Case-scoped random generator with convenience strategies.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        debug_assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.range(r.start as f64, r.end as f64) as f32
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of uniform f32 with random length in `len`.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Vector of N(0, std) f32 — the distribution quantizers see.
    pub fn vec_normal(&mut self, len: Range<usize>, std: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.normal_ms(0.0, std as f64) as f32).collect()
    }

    /// Vector of Laplace(0, b) f32 — heavy-tailed gradient-like values.
    pub fn vec_laplace(&mut self, len: Range<usize>, b: f64) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.laplace(b) as f32).collect()
    }

    /// Pick one of the provided items.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` for `cases` derived seeds; panic (with the failing seed) on
/// the first failure.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut prop: F) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with check_seeded(\"{name}\", {seed:#x}, ..)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seeded<F: FnMut(&mut Gen)>(_name: &str, seed: u64, mut prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 100, |g| {
            let x = g.f64_in(-1e6..1e6);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| panic!("boom"));
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen ranges", 200, |g| {
            let n = g.usize_in(3..17);
            assert!((3..17).contains(&n));
            let x = g.f32_in(-2.0..5.0);
            assert!((-2.0..5.0).contains(&x));
            let v = g.vec_f32(0..8, 0.0..1.0);
            assert!(v.len() < 8);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }

    #[test]
    fn prop_word_packer_roundtrips_every_width() {
        // ISSUE 3 satellite: the u64 word-at-a-time packer must
        // round-trip through the wire writer/reader at every index bit
        // width the codec can emit (1..=24 covers ceil_log2(s) for
        // every supported level count, full precision included)
        use crate::quant::codec::{BitReader, BitWriter};
        for nbits in 1u32..=24 {
            check(&format!("packer roundtrip nbits={nbits}"), 8, |g| {
                let n = g.usize_in(0..400);
                let mask = (1u64 << nbits) - 1;
                let vals: Vec<u32> = (0..n)
                    .map(|_| (g.rng().next_u64() & mask) as u32)
                    .collect();
                let signs: Vec<bool> =
                    (0..n).map(|_| g.bool()).collect();
                let mut w = BitWriter::new();
                w.write_bools(&signs);
                w.write_packed(&vals, nbits);
                assert_eq!(
                    w.bit_len(),
                    n + n * nbits as usize,
                    "bit accounting"
                );
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                let mut back_signs = Vec::new();
                r.read_bools_into(n, &mut back_signs).unwrap();
                let mut back_vals = Vec::new();
                r.read_packed_into(nbits, n, &mut back_vals).unwrap();
                assert_eq!(back_signs, signs);
                assert_eq!(back_vals, vals);
                // reading past the end must fail, not fabricate bits
                let mut overflow = Vec::new();
                assert!(r
                    .read_packed_into(nbits, bytes.len() + 8, &mut overflow)
                    .is_err());
            });
        }
    }

    #[test]
    fn prop_wire_message_roundtrips_and_never_panics() {
        // ISSUE 5 satellite: arbitrary level counts across every index
        // bit-width the u16 level-count field supports (1..=16; the
        // raw packer layer is exercised to 24 bits by the test above),
        // empty/degenerate payloads, and truncated or corrupted
        // buffers, which must ERROR — decoding is total, no panics
        use crate::quant::wire::{
            self, ImpliedCache, QuantTag, WireHeader,
        };
        use crate::quant::QuantizedVector;
        check("wire message total decoding", 60, |g| {
            let idx_bits = g.usize_in(1..17) as u32;
            let lo = (1usize << (idx_bits - 1)) + 1;
            let hi = (1usize << idx_bits).min(65535);
            let s = if idx_bits == 1 {
                2
            } else {
                g.usize_in(lo..hi + 1)
            };
            // degenerate payloads on purpose: d = 0 and zero norms
            let d = g.usize_in(0..60);
            let norm =
                if g.bool() { 0.0 } else { g.f32_in(0.0..10.0) };
            let negative: Vec<bool> = (0..d).map(|_| g.bool()).collect();
            let indices: Vec<u32> =
                (0..d).map(|_| g.rng().below(s) as u32).collect();
            let levels: Vec<f32> =
                (0..s).map(|_| g.f32_in(0.0..1.0)).collect();
            let qv = QuantizedVector {
                norm,
                negative,
                indices,
                levels,
                implied_table: false,
            };
            let h = WireHeader::new(
                QuantTag::LloydMax,
                g.rng().below(4) as u8,
                g.rng().below(1 << 20) as u32,
                g.rng().below(1 << 20) as u32,
                s,
            );
            let bytes = wire::encode(&h, &qv);
            assert_eq!(bytes.len(), wire::message_len(&qv));
            let mut cache = ImpliedCache::new();
            let mut out = QuantizedVector::empty();
            let back =
                wire::decode_into(&bytes, &mut cache, &mut out).unwrap();
            assert_eq!(back, h);
            assert_eq!(out, qv);
            // any strict prefix fails cleanly
            let cut = g.usize_in(0..bytes.len());
            assert!(
                wire::decode_into(&bytes[..cut], &mut cache, &mut out)
                    .is_err(),
                "decoded a {cut}-byte prefix of {}",
                bytes.len()
            );
            // arbitrary corruption never panics (it may error or decode
            // to some other valid message; both are acceptable)
            let mut corrupt = bytes.clone();
            let pos = g.usize_in(0..corrupt.len());
            corrupt[pos] ^= 0xFF;
            let _ = wire::decode_into(&corrupt, &mut cache, &mut out);
        });
    }

    #[test]
    fn prop_sparse_wire_roundtrips_and_is_canonical() {
        // ISSUE 10 satellite: messages with a zero level-0 and mostly
        // index-0 coordinates may take the sparse body (flags bit1).
        // The encoder must pick whichever form is strictly smaller,
        // byte accounting must stay exact either way, the bytes must
        // decode back to the same message, and truncation/corruption
        // must never panic
        use crate::quant::bits::stream_bytes;
        use crate::quant::codec::{encoded_bits, sparse_nnz};
        use crate::quant::wire::{
            self, ImpliedCache, QuantTag, WireHeader, HEADER_BYTES,
        };
        use crate::quant::QuantizedVector;
        check("sparse wire canonical roundtrip", 80, |g| {
            let s = g.usize_in(2..33);
            let d = g.usize_in(1..300);
            // density knob: from fully sparse to fully dense payloads,
            // so both body forms (and the tie region) are exercised
            let density = g.usize_in(1..9);
            let mut negative = Vec::with_capacity(d);
            let mut indices = Vec::with_capacity(d);
            for _ in 0..d {
                if g.rng().below(8) < density {
                    indices.push(1 + g.rng().below(s - 1) as u32);
                    negative.push(g.bool());
                } else {
                    // the implicit slot: index 0, positive sign
                    indices.push(0);
                    negative.push(false);
                }
            }
            let mut levels: Vec<f32> =
                (0..s).map(|_| g.f32_in(0.01..1.0)).collect();
            levels[0] = 0.0; // sparse-eligible table
            let qv = QuantizedVector {
                norm: g.f32_in(0.0..10.0),
                negative,
                indices,
                levels,
                implied_table: false,
            };
            let h = WireHeader::new(QuantTag::TopK, 2, 7, 11, s);
            let bytes = wire::encode(&h, &qv);
            assert_eq!(bytes.len(), wire::message_len(&qv));
            let dense_len = HEADER_BYTES
                + stream_bytes(encoded_bits(d, s, false));
            match sparse_nnz(&qv) {
                Some(k) => {
                    assert_eq!(
                        k,
                        qv.indices.iter().filter(|&&i| i != 0).count()
                    );
                    assert!(
                        bytes.len() < dense_len,
                        "sparse form chosen but not smaller: {} vs \
                         {dense_len}",
                        bytes.len()
                    );
                }
                None => assert_eq!(bytes.len(), dense_len),
            }
            let mut cache = ImpliedCache::new();
            let mut out = QuantizedVector::empty();
            let back =
                wire::decode_into(&bytes, &mut cache, &mut out).unwrap();
            assert_eq!(back, h);
            assert_eq!(out, qv);
            // any strict prefix fails cleanly, corruption never panics
            let cut = g.usize_in(0..bytes.len());
            assert!(wire::decode_into(
                &bytes[..cut],
                &mut cache,
                &mut out
            )
            .is_err());
            let mut corrupt = bytes.clone();
            let pos = g.usize_in(0..corrupt.len());
            corrupt[pos] ^= 0xFF;
            let _ = wire::decode_into(&corrupt, &mut cache, &mut out);
        });
    }

    #[test]
    fn prop_robust_mixing_rows_stay_stochastic_and_bounded() {
        // ISSUE 10 satellite: for arbitrary neighborhoods with a
        // normalized weight row, every mixing rule is a convex
        // combination — each output coordinate lies within the input
        // range — trimmed(0) is BITWISE plain Metropolis, and the
        // reported drop count is exactly min(2f, deg)
        use crate::config::MixingKind;
        use crate::topology::robust_mix_into;
        check("robust mixing convexity", 100, |g| {
            let dim = g.usize_in(1..20);
            let deg = g.usize_in(0..8);
            let cols: Vec<Vec<f32>> = (0..deg + 1)
                .map(|_| {
                    (0..dim)
                        .map(|_| {
                            g.rng().normal_ms(0.0, 3.0) as f32
                        })
                        .collect()
                })
                .collect();
            let raw: Vec<f64> =
                (0..deg + 1).map(|_| g.f64_in(0.1..1.0)).collect();
            let total: f64 = raw.iter().sum();
            let self_w = raw[0] / total;
            let nbrs: Vec<(&[f32], f64)> = cols[1..]
                .iter()
                .zip(&raw[1..])
                .map(|(c, w)| (c.as_slice(), *w / total))
                .collect();
            let f = g.usize_in(0..4);
            let mut plain = vec![0.0f32; dim];
            robust_mix_into(
                &mut plain,
                &cols[0],
                self_w,
                &nbrs,
                &MixingKind::Metropolis,
            );
            let mut t0 = vec![0.0f32; dim];
            robust_mix_into(
                &mut t0,
                &cols[0],
                self_w,
                &nbrs,
                &MixingKind::Trimmed { f: 0 },
            );
            for (a, b) in plain.iter().zip(&t0) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for kind in [
                MixingKind::Metropolis,
                MixingKind::Trimmed { f },
                MixingKind::Median,
            ] {
                let mut out = vec![0.0f32; dim];
                let drops = robust_mix_into(
                    &mut out,
                    &cols[0],
                    self_w,
                    &nbrs,
                    &kind,
                );
                let want_drops = match kind {
                    MixingKind::Trimmed { f } if f > 0 => {
                        (2 * f).min(deg) as u64
                    }
                    _ => 0,
                };
                assert_eq!(drops, want_drops, "{kind:?}");
                for c in 0..dim {
                    let lo = cols
                        .iter()
                        .map(|col| col[c])
                        .fold(f32::INFINITY, f32::min);
                    let hi = cols
                        .iter()
                        .map(|col| col[c])
                        .fold(f32::NEG_INFINITY, f32::max);
                    let tol = 1e-4 * (1.0 + hi.abs() + lo.abs());
                    assert!(
                        out[c] >= lo - tol && out[c] <= hi + tol,
                        "{kind:?}: coord {c} = {} outside [{lo}, {hi}]",
                        out[c]
                    );
                }
            }
        });
    }

    #[test]
    fn prop_chrome_exporter_emits_balanced_monotone_streams() {
        // PR 7 satellite: for ARBITRARY span sets — overlapping,
        // nested, zero-length, duplicate-named — the Chrome exporter
        // must keep both trace_event invariants on every (pid, tid)
        // lane: timestamps never decrease, and every B has exactly one
        // matching E closing the innermost open span
        use crate::config::json::Json;
        use crate::obs::export::{
            chrome_events, chrome_trace, ChromeSpan,
        };
        use std::collections::HashMap;
        check("chrome exporter invariants", 120, |g| {
            let n = g.usize_in(0..40);
            let spans: Vec<ChromeSpan> = (0..n)
                .map(|k| ChromeSpan {
                    pid: g.usize_in(0..3) as u32,
                    tid: g.usize_in(0..4) as u32,
                    name: format!("s{}", k % 5),
                    ts_ns: g.usize_in(0..10_000) as u64,
                    dur_ns: g.usize_in(0..5_000) as u64,
                })
                .collect();
            let ev = chrome_events(&spans);
            assert_eq!(ev.len(), 2 * n, "one B and one E per span");
            let mut last: HashMap<(u32, u32), u64> = HashMap::new();
            let mut stacks: HashMap<(u32, u32), Vec<String>> =
                HashMap::new();
            for e in &ev {
                let lane = (e.pid, e.tid);
                let prev = last.entry(lane).or_insert(0);
                assert!(
                    e.ts_ns >= *prev,
                    "lane {lane:?}: ts decreased {prev} -> {}",
                    e.ts_ns
                );
                *prev = e.ts_ns;
                let stack = stacks.entry(lane).or_default();
                if e.begin {
                    stack.push(e.name.clone());
                } else {
                    let open = stack.pop().expect("E without open B");
                    assert_eq!(open, e.name, "E must close innermost B");
                }
            }
            for (lane, stack) in stacks {
                assert!(
                    stack.is_empty(),
                    "lane {lane:?}: {} unclosed spans",
                    stack.len()
                );
            }
            // the rendered document is valid JSON with 2n events
            let doc = Json::parse(&chrome_trace(&spans)).unwrap();
            let events =
                doc.get("traceEvents").unwrap().as_arr().unwrap();
            assert_eq!(events.len(), 2 * n);
        });
    }

    #[test]
    fn prop_sparse_metropolis_is_bitwise_equal_to_dense_oracle() {
        // PR 8 satellite: the direct sparse Metropolis constructor —
        // the only builder above DENSE_ORACLE_MAX — must reproduce the
        // dense construction BITWISE on arbitrary random graphs: same
        // neighbor ordering, same f64 bits in every weight
        use crate::config::TopologyKind;
        use crate::topology::{
            metropolis_weights, SparseTopology, Topology,
        };
        check("sparse metropolis == dense oracle", 60, |g| {
            let n = g.usize_in(2..65);
            let p = g.f64_in(0.05..0.9);
            let seed = g.rng().next_u64();
            let t = Topology::build(
                &TopologyKind::Random { p },
                n,
                seed,
            );
            let direct = SparseTopology::metropolis(&t.adj);
            let oracle = SparseTopology::from_dense(
                &metropolis_weights(&t.adj),
            );
            assert_eq!(direct.n(), oracle.n());
            for i in 0..n {
                assert_eq!(
                    direct.self_weight(i).to_bits(),
                    oracle.self_weight(i).to_bits(),
                    "node {i}: self weight bits differ"
                );
                let (dr, or) = (direct.row(i), oracle.row(i));
                assert_eq!(dr.len(), or.len(), "row {i} length");
                for (a, b) in dr.iter().zip(or) {
                    assert_eq!(a.0, b.0, "row {i}: neighbor order");
                    assert_eq!(
                        a.1.to_bits(),
                        b.1.to_bits(),
                        "row {i}: weight bits for neighbor {}",
                        a.0
                    );
                }
            }
        });
    }

    #[test]
    fn prop_power_zeta_matches_jacobi_within_1e6() {
        // PR 8 satellite: deflated power iteration at the Oracle
        // budget agrees with the dense Jacobi ζ within 1e-6 on
        // arbitrary Metropolis graphs n ≤ 64 — including disconnected
        // draws (ζ = 1) and near-degenerate spectra
        use crate::config::TopologyKind;
        use crate::linalg::eigen::second_largest_abs_eigenvalue;
        use crate::linalg::power::PowerBudget;
        use crate::topology::{
            metropolis_weights, SparseTopology, Topology,
        };
        check("power zeta == jacobi", 40, |g| {
            let n = g.usize_in(2..65);
            let p = g.f64_in(0.05..0.9);
            let seed = g.rng().next_u64();
            let t = Topology::build(
                &TopologyKind::Random { p },
                n,
                seed,
            );
            let sp = SparseTopology::metropolis(&t.adj);
            let z_pow = sp.zeta_power(PowerBudget::Oracle);
            let z_jac = second_largest_abs_eigenvalue(
                &metropolis_weights(&t.adj),
            );
            assert!(
                (z_pow - z_jac).abs() <= 1e-6,
                "power {z_pow} vs jacobi {z_jac} (n={n}, p={p})"
            );
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        check("det-a", 5, |g| out1.push(g.rng().next_u64()));
        check("det-a", 5, |g| out2.push(g.rng().next_u64()));
        // NOTE: closures mutate captured vecs; both runs see same seeds.
        assert_eq!(out1, out2);
    }
}
