//! Training metrics: per-round records, CSV/JSON writers, run summaries.
//!
//! Every experiment driver appends [`RoundRecord`]s to a [`RunLog`]; the
//! figure benches print the same series the paper plots (loss vs iteration,
//! loss vs communicated bits / time progression, accuracy, distortion).

use std::io::Write;
use std::path::Path;

use crate::config::json::Json;

pub mod stream;

pub use stream::{
    csv_row, CsvStream, JsonlStream, LogSink, RecordSink, RunSummary,
    CSV_HEADER,
};

/// One communication round's measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// global loss F(u_k) on the averaged model
    pub loss: f64,
    /// test accuracy of the averaged model (NaN if not evaluated)
    pub accuracy: f64,
    /// cumulative bits sent over a single directed link (paper's B metric)
    pub bits_per_link: u64,
    /// normalized quantization distortion E||Q(x)-x||^2 / ||x||^2 this round
    pub distortion: f64,
    /// number of quantization levels used this round (s_k)
    pub levels: usize,
    /// learning rate used this round
    pub lr: f64,
    /// wall-clock seconds spent in this round
    pub wall_secs: f64,
    /// cumulative *virtual* seconds on the simulated fabric at the end
    /// of this round (0 outside `run_simulated`; monotone within a run)
    pub virtual_secs: f64,
    /// mean virtual seconds nodes idled at this round's straggler
    /// barrier (simnet runs only)
    pub straggler_wait_secs: f64,
    /// cumulative MEASURED wire bytes — the exact encoded
    /// [`crate::quant::wire`] message lengths. Simulated runs count
    /// every transmitted link copy (the fabric's byte meter); plain
    /// matrix runs count per-broadcast size × out-degree; the threaded
    /// runtime counts the bytes each node actually sent per link
    pub wire_bytes: u64,
}

/// A full run: config echo + round series.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub name: String,
    pub records: Vec<RoundRecord>,
}

impl RunLog {
    pub fn new(name: &str) -> Self {
        RunLog { name: name.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.records
            .iter()
            .rev()
            .find(|r| !r.accuracy.is_nan())
            .map(|r| r.accuracy)
    }

    pub fn total_bits(&self) -> u64 {
        self.records.last().map_or(0, |r| r.bits_per_link)
    }

    /// Time progression in seconds at the paper's link rate.
    pub fn time_progression(&self, link_bps: f64) -> Vec<f64> {
        self.records
            .iter()
            .map(|r| r.bits_per_link as f64 / link_bps)
            .collect()
    }

    /// Simulated time progression: the cumulative virtual clock per
    /// round (all zeros unless the run went through a simnet fabric).
    pub fn virtual_time_progression(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.virtual_secs).collect()
    }

    /// The first record at or below the target loss — the single
    /// definition of "reached the target" every to-target accessor and
    /// report shares.
    pub fn record_at_loss(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.loss <= target)
    }

    /// Virtual seconds needed to reach the target loss (simnet runs).
    pub fn virtual_secs_to_loss(&self, target: f64) -> Option<f64> {
        self.record_at_loss(target).map(|r| r.virtual_secs)
    }

    /// First round index at which loss <= target (communication-efficiency
    /// comparisons: "bits to reach targeted training loss").
    pub fn rounds_to_loss(&self, target: f64) -> Option<usize> {
        self.record_at_loss(target).map(|r| r.round)
    }

    /// Bits on one link needed to reach the target loss.
    pub fn bits_to_loss(&self, target: f64) -> Option<u64> {
        self.record_at_loss(target).map(|r| r.bits_per_link)
    }

    /// Render the whole log as CSV. The streaming
    /// [`CsvStream`](stream::CsvStream) writes the same bytes row by
    /// row — both build on [`CSV_HEADER`] / [`csv_row`], so buffered
    /// and streamed output are identical by construction.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(CSV_HEADER);
        out.push('\n');
        for r in &self.records {
            out.push_str(&csv_row(r));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::num(r.round as f64)),
                                ("loss", Json::num(r.loss)),
                                ("accuracy", Json::num(r.accuracy)),
                                (
                                    "bits_per_link",
                                    Json::num(r.bits_per_link as f64),
                                ),
                                ("distortion", Json::num(r.distortion)),
                                ("levels", Json::num(r.levels as f64)),
                                ("lr", Json::num(r.lr)),
                                ("wall_secs", Json::num(r.wall_secs)),
                                (
                                    "virtual_secs",
                                    Json::num(r.virtual_secs),
                                ),
                                (
                                    "straggler_wait_secs",
                                    Json::num(r.straggler_wait_secs),
                                ),
                                (
                                    "wire_bytes",
                                    Json::num(r.wire_bytes as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Parse a CSV produced by [`RunLog::to_csv`] back into records.
    /// Strict: the header must match the writer's exactly and every
    /// row needs all 11 columns. `NaN` cells (unevaluated accuracy)
    /// parse back to NaN, so write→parse round-trips bit-exactly
    /// (f64's `Display` prints the shortest exact representation).
    pub fn from_csv(name: &str, text: &str) -> anyhow::Result<RunLog> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("").trim();
        anyhow::ensure!(
            header == CSV_HEADER,
            "RunLog CSV: unexpected header '{header}'"
        );
        let mut log = RunLog::new(name);
        for (i, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let row = i + 2; // 1-based, after the header
            let cells: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(
                cells.len() == 11,
                "RunLog CSV row {row}: {} fields, expected 11",
                cells.len()
            );
            let f = |k: usize| -> anyhow::Result<f64> {
                cells[k].parse().map_err(|_| {
                    anyhow::anyhow!(
                        "RunLog CSV row {row}: bad number '{}'",
                        cells[k]
                    )
                })
            };
            let u = |k: usize| -> anyhow::Result<u64> {
                cells[k].parse().map_err(|_| {
                    anyhow::anyhow!(
                        "RunLog CSV row {row}: bad integer '{}'",
                        cells[k]
                    )
                })
            };
            log.push(RoundRecord {
                round: u(0)? as usize,
                loss: f(1)?,
                accuracy: f(2)?,
                bits_per_link: u(3)?,
                distortion: f(4)?,
                levels: u(5)? as usize,
                lr: f(6)?,
                wall_secs: f(7)?,
                virtual_secs: f(8)?,
                straggler_wait_secs: f(9)?,
                wire_bytes: u(10)?,
            });
        }
        Ok(log)
    }

    /// Parse the [`RunLog::to_json`] document back. JSON has no NaN:
    /// the writer emits non-finite numbers as `null`, which reads
    /// back as NaN here (absent float fields do the same).
    pub fn from_json(j: &Json) -> anyhow::Result<RunLog> {
        let name = j
            .get_str("name")
            .ok_or_else(|| anyhow::anyhow!("RunLog JSON: no name"))?;
        let recs = j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                anyhow::anyhow!("RunLog JSON: no records array")
            })?;
        let mut log = RunLog::new(name);
        for (i, r) in recs.iter().enumerate() {
            let f = |k: &str| r.get_f64(k).unwrap_or(f64::NAN);
            let u = |k: &str| -> anyhow::Result<u64> {
                r.get_f64(k).map(|v| v as u64).ok_or_else(|| {
                    anyhow::anyhow!("RunLog JSON record {i}: no {k}")
                })
            };
            log.push(RoundRecord {
                round: u("round")? as usize,
                loss: f("loss"),
                accuracy: f("accuracy"),
                bits_per_link: u("bits_per_link")?,
                distortion: f("distortion"),
                levels: u("levels")? as usize,
                lr: f("lr"),
                wall_secs: f("wall_secs"),
                virtual_secs: f("virtual_secs"),
                straggler_wait_secs: f("straggler_wait_secs"),
                wire_bytes: u("wire_bytes")?,
            });
        }
        Ok(log)
    }
}

/// Console table printer for the figure benches — fixed-width columns so
/// the bench output reads like the paper's series.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format a float with engineering-style short precision for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e6 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, loss: f64, bits: u64) -> RoundRecord {
        RoundRecord {
            round,
            loss,
            accuracy: f64::NAN,
            bits_per_link: bits,
            distortion: 0.01,
            levels: 16,
            lr: 0.05,
            wall_secs: 0.1,
            virtual_secs: round as f64 * 2.0,
            straggler_wait_secs: 0.0,
            wire_bytes: bits / 8 * 10,
        }
    }

    #[test]
    fn wire_bytes_serialized_in_csv_and_json() {
        let mut log = RunLog::new("w");
        log.push(rec(1, 2.0, 800));
        let csv = log.to_csv();
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().next().unwrap().ends_with("wire_bytes"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",1000"));
        let j = log.to_json().to_string();
        assert!(j.contains("\"wire_bytes\""), "{j}");
    }

    #[test]
    fn virtual_time_series_and_target() {
        let mut log = RunLog::new("t");
        log.push(rec(1, 2.0, 100));
        log.push(rec(2, 1.0, 200));
        assert_eq!(log.virtual_time_progression(), vec![2.0, 4.0]);
        assert_eq!(log.virtual_secs_to_loss(1.5), Some(4.0));
        assert_eq!(log.virtual_secs_to_loss(0.5), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::new("t");
        log.push(rec(1, 2.0, 100));
        log.push(rec(2, 1.0, 200));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("round,loss"));
    }

    #[test]
    fn bits_and_rounds_to_loss() {
        let mut log = RunLog::new("t");
        log.push(rec(1, 2.0, 100));
        log.push(rec(2, 1.0, 200));
        log.push(rec(3, 0.5, 300));
        assert_eq!(log.rounds_to_loss(1.0), Some(2));
        assert_eq!(log.bits_to_loss(0.6), Some(300));
        assert_eq!(log.bits_to_loss(0.1), None);
        assert_eq!(log.total_bits(), 300);
    }

    #[test]
    fn time_progression_scales_bits() {
        let mut log = RunLog::new("t");
        log.push(rec(1, 2.0, 100_000_000));
        let t = log.time_progression(100e6);
        assert!((t[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrips_structure() {
        let mut log = RunLog::new("t");
        log.push(rec(1, 2.0, 100));
        let j = log.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get_str("name"), Some("t"));
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    /// Bitwise record equality: `PartialEq` can't compare the NaN
    /// accuracy of unevaluated rounds, `to_bits` can.
    fn same(a: &RoundRecord, b: &RoundRecord) -> bool {
        let fe = |x: f64, y: f64| x.to_bits() == y.to_bits();
        a.round == b.round
            && fe(a.loss, b.loss)
            && fe(a.accuracy, b.accuracy)
            && a.bits_per_link == b.bits_per_link
            && fe(a.distortion, b.distortion)
            && a.levels == b.levels
            && fe(a.lr, b.lr)
            && fe(a.wall_secs, b.wall_secs)
            && fe(a.virtual_secs, b.virtual_secs)
            && fe(a.straggler_wait_secs, b.straggler_wait_secs)
            && a.wire_bytes == b.wire_bytes
    }

    /// Sample with awkward values: a NaN-accuracy row (not evaluated),
    /// a subnormal-ish loss, and a large wire_bytes count.
    fn awkward_log() -> RunLog {
        let mut log = RunLog::new("rt");
        log.push(rec(1, 2.0, 800));
        let mut r = rec(2, 1.25e-7, 1600);
        r.accuracy = 0.875;
        r.straggler_wait_secs = 0.001953125;
        r.wire_bytes = 123_456_789_012;
        log.push(r);
        log
    }

    #[test]
    fn csv_roundtrips_records_including_nan_and_wire_bytes() {
        let log = awkward_log();
        let back = RunLog::from_csv("rt", &log.to_csv()).unwrap();
        assert_eq!(back.name, "rt");
        assert_eq!(back.records.len(), log.records.len());
        for (a, b) in log.records.iter().zip(&back.records) {
            assert!(same(a, b), "CSV round-trip changed {a:?} -> {b:?}");
        }
        assert!(back.records[0].accuracy.is_nan());
        assert_eq!(back.records[1].wire_bytes, 123_456_789_012);
    }

    #[test]
    fn csv_parser_rejects_malformed_input() {
        assert!(RunLog::from_csv("x", "").is_err());
        assert!(RunLog::from_csv("x", "round,loss\n1,2\n").is_err());
        let good = awkward_log().to_csv();
        let header = good.lines().next().unwrap();
        // a row with a missing column
        let bad = format!("{header}\n1,2.0,NaN,800\n");
        assert!(RunLog::from_csv("x", &bad).is_err());
        // a row with a non-numeric cell
        let bad = format!(
            "{header}\n1,2.0,NaN,800,0.01,16,0.05,0.1,2,0,oops\n"
        );
        assert!(RunLog::from_csv("x", &bad).is_err());
    }

    #[test]
    fn json_roundtrips_records_including_nan_and_wire_bytes() {
        let log = awkward_log();
        // through the actual serialized text, not just the Json tree:
        // NaN is emitted as null and must come back as NaN
        let text = log.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = RunLog::from_json(&parsed).unwrap();
        assert_eq!(back.name, log.name);
        assert_eq!(back.records.len(), log.records.len());
        for (a, b) in log.records.iter().zip(&back.records) {
            assert!(
                same(a, b),
                "JSON round-trip changed {a:?} -> {b:?}"
            );
        }
        assert!(back.records[0].accuracy.is_nan());
        assert_eq!(back.records[1].wire_bytes, 123_456_789_012);
        // structural errors are reported, not defaulted
        assert!(RunLog::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric"]);
        t.row(vec!["1".into(), "2.5".into()]);
        t.row(vec!["100".into(), "3".into()]);
        let s = t.render();
        assert!(s.contains("a  metric"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(0.0), "0");
        assert!(fnum(1234567.0).contains('e'));
        assert!(fnum(0.25).starts_with("0.25"));
    }
}
