//! Streaming run output: write records to a sink as they are produced
//! instead of buffering the whole run.
//!
//! A 10k-node run holds per-round [`RoundRecord`]s (and, async, one
//! [`NodeRecord`](crate::agossip::NodeRecord) per node per local
//! round) — buffering all of it is O(rounds · n) memory for data the
//! caller usually just writes to disk. [`CsvStream`] emits exactly the
//! bytes [`RunLog::to_csv`](super::RunLog::to_csv) would have produced
//! (both are built from [`CSV_HEADER`] / [`csv_row`], so parity is by
//! construction and `rust/tests/streaming_parity.rs` enforces it), and
//! [`JsonlStream`] appends one JSON document per line for per-node
//! series. [`RunSummary`] is what a streamed run returns in place of
//! the full log: the scalar facts drivers and benches actually read.

use std::io::Write;

use crate::config::json::Json;

use super::RoundRecord;

/// The one CSV header every writer emits and every parser requires.
pub const CSV_HEADER: &str = "round,loss,accuracy,bits_per_link,\
                              distortion,levels,lr,wall_secs,\
                              virtual_secs,straggler_wait_secs,\
                              wire_bytes";

/// One CSV row (no trailing newline) — the single row format shared by
/// the buffered writer and the streaming sink.
pub fn csv_row(r: &RoundRecord) -> String {
    format!(
        "{},{},{},{},{},{},{},{},{},{},{}",
        r.round,
        r.loss,
        r.accuracy,
        r.bits_per_link,
        r.distortion,
        r.levels,
        r.lr,
        r.wall_secs,
        r.virtual_secs,
        r.straggler_wait_secs,
        r.wire_bytes
    )
}

/// Where a streamed run's per-round records go.
pub trait RecordSink {
    fn record(&mut self, r: &RoundRecord) -> anyhow::Result<()>;
}

/// Stream records as CSV, byte-identical to the buffered
/// [`RunLog::to_csv`](super::RunLog::to_csv) output for the same
/// record sequence.
pub struct CsvStream<W: Write> {
    w: W,
}

impl<W: Write> CsvStream<W> {
    /// Write the header immediately and stream rows from then on.
    pub fn new(mut w: W) -> std::io::Result<Self> {
        writeln!(w, "{CSV_HEADER}")?;
        Ok(CsvStream { w })
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }

    /// Flush and hand back the writer.
    pub fn finish(mut self) -> std::io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

impl<W: Write> RecordSink for CsvStream<W> {
    fn record(&mut self, r: &RoundRecord) -> anyhow::Result<()> {
        writeln!(self.w, "{}", csv_row(r))?;
        Ok(())
    }
}

/// Collect records into a [`RunLog`](super::RunLog) — the buffered
/// sink, for call sites that want the streaming API shape without a
/// file (tests, small runs).
pub struct LogSink(pub super::RunLog);

impl LogSink {
    pub fn new(name: &str) -> Self {
        LogSink(super::RunLog::new(name))
    }
}

impl RecordSink for LogSink {
    fn record(&mut self, r: &RoundRecord) -> anyhow::Result<()> {
        self.0.push(r.clone());
        Ok(())
    }
}

/// Stream JSON documents one per line (JSONL) — the per-node record
/// sink of the async engine.
pub struct JsonlStream<W: Write> {
    w: W,
    lines: u64,
}

impl<W: Write> JsonlStream<W> {
    pub fn new(w: W) -> Self {
        JsonlStream { w, lines: 0 }
    }

    pub fn push(&mut self, doc: &Json) -> std::io::Result<()> {
        writeln!(self.w, "{}", doc.to_string())?;
        self.lines += 1;
        Ok(())
    }

    /// Lines written so far.
    pub fn lines(&self) -> u64 {
        self.lines
    }

    pub fn finish(mut self) -> std::io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// The scalar outcome of a streamed run — what remains in memory when
/// records go straight to a sink.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    /// records emitted
    pub rounds: usize,
    /// loss of the last record
    pub last_loss: f64,
    /// last evaluated (non-NaN) accuracy
    pub final_accuracy: f64,
    /// cumulative per-link bits of the last record
    pub total_bits: u64,
    /// cumulative wire bytes of the last record
    pub wire_bytes: u64,
    /// virtual clock of the last record (simnet runs)
    pub virtual_secs: f64,
    /// process peak RSS (`VmHWM`) when the run finished — the same
    /// figure bench JSON and sweep manifests report. `None` off-Linux.
    pub peak_rss_bytes: Option<u64>,
}

impl RunSummary {
    pub fn observe(&mut self, r: &RoundRecord) {
        self.rounds += 1;
        self.last_loss = r.loss;
        if !r.accuracy.is_nan() {
            self.final_accuracy = r.accuracy;
        }
        self.total_bits = r.bits_per_link;
        self.wire_bytes = r.wire_bytes;
        self.virtual_secs = r.virtual_secs;
    }

    /// Stamp the current process peak RSS into the summary (called
    /// once, when the run's records have all been emitted).
    pub fn stamp_peak_rss(&mut self) {
        self.peak_rss_bytes = crate::bench::peak_rss_bytes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunLog;

    fn rec(round: usize, loss: f64) -> RoundRecord {
        RoundRecord {
            round,
            loss,
            accuracy: if round % 2 == 0 { 0.5 } else { f64::NAN },
            bits_per_link: round as u64 * 100,
            distortion: 0.01,
            levels: 16,
            lr: 0.05,
            wall_secs: 0.1,
            virtual_secs: round as f64,
            straggler_wait_secs: 0.0,
            wire_bytes: round as u64 * 800,
        }
    }

    #[test]
    fn csv_stream_matches_buffered_writer_bytewise() {
        let mut log = RunLog::new("s");
        let mut sink = CsvStream::new(Vec::new()).unwrap();
        for k in 1..=5 {
            let r = rec(k, 2.0 / k as f64);
            sink.record(&r).unwrap();
            log.push(r);
        }
        let streamed = sink.finish().unwrap();
        assert_eq!(String::from_utf8(streamed).unwrap(), log.to_csv());
    }

    #[test]
    fn streamed_csv_parses_back() {
        let mut sink = CsvStream::new(Vec::new()).unwrap();
        let rows: Vec<RoundRecord> = (1..=3).map(|k| rec(k, 1.0)).collect();
        for r in &rows {
            sink.record(r).unwrap();
        }
        let text =
            String::from_utf8(sink.finish().unwrap()).unwrap();
        let back = RunLog::from_csv("s", &text).unwrap();
        assert_eq!(back.records.len(), 3);
        assert!(back.records[0].accuracy.is_nan());
        assert_eq!(back.records[1].accuracy, 0.5);
    }

    #[test]
    fn jsonl_stream_writes_one_doc_per_line() {
        let mut s = JsonlStream::new(Vec::new());
        s.push(&Json::obj(vec![("a", Json::num(1.0))])).unwrap();
        s.push(&Json::obj(vec![("a", Json::num(2.0))])).unwrap();
        assert_eq!(s.lines(), 2);
        let text = String::from_utf8(s.finish().unwrap()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn summary_tracks_last_and_final() {
        let mut s = RunSummary::default();
        for k in 1..=4 {
            s.observe(&rec(k, 4.0 - k as f64));
        }
        assert_eq!(s.rounds, 4);
        assert_eq!(s.last_loss, 0.0);
        assert_eq!(s.final_accuracy, 0.5); // round 4 evaluated
        assert_eq!(s.total_bits, 400);
        assert_eq!(s.virtual_secs, 4.0);
    }

    #[test]
    fn log_sink_collects() {
        let mut s = LogSink::new("x");
        s.record(&rec(1, 1.0)).unwrap();
        assert_eq!(s.0.records.len(), 1);
    }
}
