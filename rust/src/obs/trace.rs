//! In-memory telemetry recorder: spans, counters, histograms.
//!
//! The [`Recorder`] is the single buffer behind the global handle in
//! [`crate::obs`]: instrumentation pushes records under a short mutex
//! hold and nothing touches the filesystem until [`crate::obs::stop`]
//! flushes the whole buffer through [`crate::obs::export`]. Tracing
//! never does io on the hot path and never reads the wall clock into
//! any simulated quantity — recording only *observes* engine state, so
//! the simnet determinism contract (byte-identical event digests and
//! RunLogs) holds with tracing on or off.

use std::collections::BTreeMap;
use std::time::Instant;

use super::ObserveConfig;

/// One recorded span.
///
/// Two clock domains share the record: wall spans (`virt == false`)
/// carry nanoseconds since the recorder started and a recording-thread
/// id; virtual spans (`virt == true`) carry simnet virtual nanoseconds
/// and use the *node* id as the thread id, so Chrome/Perfetto renders
/// one lane per node on the virtual timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRec {
    pub rank: usize,
    pub name: String,
    /// false = wall clock, true = simnet virtual clock
    pub virt: bool,
    /// recording thread (wall) or node id (virtual)
    pub tid: u32,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

/// Fixed-bucket log2 histogram: bucket `i` counts values `v` with
/// `floor(log2(v)) == i` (`v == 0` lands in bucket 0), so nanosecond
/// latencies from 1 ns to ~584 years fit in 64 buckets with no
/// configuration.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Hist {
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<u64>,
}

impl Hist {
    pub fn record(&mut self, v: u64) {
        let b = if v == 0 {
            0
        } else {
            63 - v.leading_zeros() as usize
        };
        if self.buckets.len() <= b {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper edge (2^(i+1)) of the bucket holding the p-quantile, in
    /// the recorded unit — an upper bound, exact to a factor of 2.
    pub fn quantile_edge(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (p * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target.max(1) {
                return 1u64 << (i + 1).min(63);
            }
        }
        1u64 << self.buckets.len().min(63)
    }

    /// Merge another histogram into this one (bucket-wise).
    pub fn absorb(&mut self, other: &Hist) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (b, &n) in other.buckets.iter().enumerate() {
            self.buckets[b] += n;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

/// Most distinct keys a single counter name may hold; further keys
/// collapse into [`OVERFLOW_KEY`]. Dynamic keys (per-node link
/// counters) would otherwise grow with the deployment — at 10k nodes
/// an uncapped per-link scheme held ~80k strings per counter name.
pub const MAX_KEYS_PER_COUNTER: usize = 256;

/// The bucket absorbing counter increments past the key cap.
pub const OVERFLOW_KEY: &str = "other";

/// The buffer every instrumentation call appends to.
pub(crate) struct Recorder {
    pub rank: usize,
    pub start: Instant,
    pub trace_path: Option<String>,
    pub chrome_path: Option<String>,
    pub spans: Vec<SpanRec>,
    pub counters: BTreeMap<(String, String), u64>,
    /// distinct keys held per counter name (enforces the cap without
    /// scanning the map)
    key_counts: BTreeMap<String, usize>,
    pub hists: BTreeMap<String, Hist>,
}

impl Recorder {
    pub fn new(cfg: &ObserveConfig, rank: usize) -> Self {
        Recorder {
            rank,
            start: Instant::now(),
            trace_path: cfg.trace_path.clone(),
            chrome_path: cfg.chrome_path.clone(),
            spans: Vec::new(),
            counters: BTreeMap::new(),
            key_counts: BTreeMap::new(),
            hists: BTreeMap::new(),
        }
    }

    pub fn wall_span(
        &mut self,
        name: &str,
        tid: u32,
        started: Instant,
        dur_ns: u64,
    ) {
        // saturates to 0 if `started` raced the recorder installation
        let ts_ns = started.duration_since(self.start).as_nanos() as u64;
        self.spans.push(SpanRec {
            rank: self.rank,
            name: name.to_string(),
            virt: false,
            tid,
            ts_ns,
            dur_ns,
        });
    }

    pub fn virt_span(
        &mut self,
        name: &str,
        node: u32,
        start_ns: u64,
        end_ns: u64,
    ) {
        self.spans.push(SpanRec {
            rank: self.rank,
            name: name.to_string(),
            virt: true,
            tid: node,
            ts_ns: start_ns,
            dur_ns: end_ns.saturating_sub(start_ns),
        });
    }

    pub fn counter(&mut self, name: &str, key: &str, n: u64) {
        if let Some(v) =
            self.counters.get_mut(&(name.to_string(), key.to_string()))
        {
            *v += n;
            return;
        }
        let held = self.key_counts.entry(name.to_string()).or_insert(0);
        if *held >= MAX_KEYS_PER_COUNTER {
            // cardinality cap: unseen keys collapse into one bucket so
            // per-entity counters stay bounded at any deployment size
            *self
                .counters
                .entry((name.to_string(), OVERFLOW_KEY.to_string()))
                .or_insert(0) += n;
            return;
        }
        *held += 1;
        self.counters
            .insert((name.to_string(), key.to_string()), n);
    }

    pub fn hist(&mut self, name: &str, v: u64) {
        self.hists.entry(name.to_string()).or_default().record(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_are_log2() {
        let mut h = Hist::default();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1024] {
            h.record(v);
        }
        assert_eq!(h.count, 8);
        assert_eq!(h.sum, 1049);
        // 0,1 -> b0; 2,3 -> b1; 4,7 -> b2; 8 -> b3; 1024 -> b10
        assert_eq!(h.buckets[0], 2);
        assert_eq!(h.buckets[1], 2);
        assert_eq!(h.buckets[2], 2);
        assert_eq!(h.buckets[3], 1);
        assert_eq!(h.buckets[10], 1);
        assert!((h.mean() - 1049.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn counter_key_cardinality_is_capped() {
        let cfg = ObserveConfig {
            trace_path: Some("unused".into()),
            chrome_path: None,
        };
        let mut r = Recorder::new(&cfg, 0);
        // 4x the cap of distinct keys, 1 each
        for i in 0..MAX_KEYS_PER_COUNTER * 4 {
            r.counter("link_send", &format!("{i}"), 1);
        }
        let held = r
            .counters
            .keys()
            .filter(|(name, _)| name == "link_send")
            .count();
        assert_eq!(held, MAX_KEYS_PER_COUNTER + 1, "cap + other bucket");
        let other = r.counters
            [&("link_send".to_string(), OVERFLOW_KEY.to_string())];
        assert_eq!(
            other,
            (MAX_KEYS_PER_COUNTER * 3) as u64,
            "all overflow increments land in the other bucket"
        );
        // capped keys keep accumulating normally
        r.counter("link_send", "0", 5);
        assert_eq!(
            r.counters[&("link_send".to_string(), "0".to_string())],
            6
        );
        // the cap is per counter name, not global
        r.counter("unrelated", "key", 1);
        assert_eq!(
            r.counters[&("unrelated".to_string(), "key".to_string())],
            1
        );
    }

    #[test]
    fn hist_quantiles_and_absorb() {
        let mut a = Hist::default();
        for _ in 0..99 {
            a.record(100); // bucket 6 (64..128)
        }
        a.record(1 << 20); // one big outlier
        assert_eq!(a.quantile_edge(0.5), 128);
        assert_eq!(a.quantile_edge(0.99), 128);
        assert_eq!(a.quantile_edge(1.0), 1 << 21);
        let mut b = Hist::default();
        b.record(100);
        b.absorb(&a);
        assert_eq!(b.count, 101);
        assert_eq!(b.buckets[6], 100);
    }
}
