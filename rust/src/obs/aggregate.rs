//! Rank-merged aggregation of parsed traces — the one set of rollups
//! behind both the human `lmdfl trace` summary and the tidy CSVs of
//! `lmdfl analyse`. Everything here is deterministic: aggregates come
//! back in a fixed order for identical inputs, so CSVs built from them
//! are byte-stable.

use std::collections::BTreeMap;

use super::export::TraceFile;
use super::trace::Hist;

/// All spans of one (name, clock) pair, merged across ranks.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanAgg {
    pub name: String,
    /// false = wall clock, true = simnet virtual clock
    pub virt: bool,
    pub count: u64,
    pub total_ns: u64,
}

impl SpanAgg {
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }

    /// "wall" / "virtual" — the trace-schema clock label.
    pub fn clock(&self) -> &'static str {
        if self.virt {
            "virtual"
        } else {
            "wall"
        }
    }
}

/// Spans aggregated by (name, clock), heaviest total first (ties break
/// on name then clock, so the order is fully deterministic).
pub fn spans(tf: &TraceFile) -> Vec<SpanAgg> {
    let mut agg: BTreeMap<(String, bool), (u64, u64)> = BTreeMap::new();
    for s in &tf.spans {
        let e = agg.entry((s.name.clone(), s.virt)).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.saturating_add(s.dur_ns);
    }
    let mut rows: Vec<SpanAgg> = agg
        .into_iter()
        .map(|((name, virt), (count, total_ns))| SpanAgg {
            name,
            virt,
            count,
            total_ns,
        })
        .collect();
    rows.sort_by(|a, b| {
        (std::cmp::Reverse(a.total_ns), &a.name, a.virt)
            .cmp(&(std::cmp::Reverse(b.total_ns), &b.name, b.virt))
    });
    rows
}

/// One counter's per-key value summed over every rank.
#[derive(Clone, Debug, PartialEq)]
pub struct CtrAgg {
    pub name: String,
    pub key: String,
    pub value: u64,
}

/// Counters summed across ranks by (name, key), in (name, key) order.
pub fn counters(tf: &TraceFile) -> Vec<CtrAgg> {
    let mut agg: BTreeMap<(String, String), u64> = BTreeMap::new();
    for c in &tf.counters {
        *agg.entry((c.name.clone(), c.key.clone())).or_insert(0) +=
            c.value;
    }
    agg.into_iter()
        .map(|((name, key), value)| CtrAgg { name, key, value })
        .collect()
}

/// Per-name counter totals (every rank, every key), in name order.
pub fn counter_totals(tf: &TraceFile) -> Vec<(String, u64)> {
    let mut totals: BTreeMap<String, u64> = BTreeMap::new();
    for c in &tf.counters {
        *totals.entry(c.name.clone()).or_insert(0) += c.value;
    }
    totals.into_iter().collect()
}

/// One histogram merged across every rank that recorded it.
#[derive(Clone, Debug, PartialEq)]
pub struct HistAgg {
    pub name: String,
    pub hist: Hist,
}

impl HistAgg {
    pub fn p50(&self) -> u64 {
        self.hist.quantile_edge(0.5)
    }

    pub fn p90(&self) -> u64 {
        self.hist.quantile_edge(0.9)
    }

    pub fn p99(&self) -> u64 {
        self.hist.quantile_edge(0.99)
    }
}

/// Histograms merged across ranks by name (bucket-wise absorb), in
/// name order.
pub fn hists(tf: &TraceFile) -> Vec<HistAgg> {
    let mut agg: BTreeMap<String, Hist> = BTreeMap::new();
    for h in &tf.hists {
        agg.entry(h.name.clone()).or_default().absorb(&h.hist);
    }
    agg.into_iter()
        .map(|(name, hist)| HistAgg { name, hist })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::{CtrRec, HistRec};
    use crate::obs::SpanRec;

    fn sample() -> TraceFile {
        let mut h0 = Hist::default();
        for _ in 0..9 {
            h0.record(100); // bucket 6 (64..128)
        }
        let mut h1 = Hist::default();
        h1.record(1 << 20);
        TraceFile {
            schema: crate::obs::TRACE_SCHEMA.to_string(),
            spans: vec![
                SpanRec {
                    rank: 0,
                    name: "round".into(),
                    virt: false,
                    tid: 0,
                    ts_ns: 0,
                    dur_ns: 1_000,
                },
                SpanRec {
                    rank: 1,
                    name: "round".into(),
                    virt: false,
                    tid: 0,
                    ts_ns: 0,
                    dur_ns: 3_000,
                },
                SpanRec {
                    rank: 0,
                    name: "mix".into(),
                    virt: true,
                    tid: 2,
                    ts_ns: 0,
                    dur_ns: 10_000,
                },
            ],
            counters: vec![
                CtrRec {
                    rank: 0,
                    name: "frame_send".into(),
                    key: "0->1".into(),
                    value: 7,
                },
                CtrRec {
                    rank: 1,
                    name: "frame_send".into(),
                    key: "0->1".into(),
                    value: 5,
                },
                CtrRec {
                    rank: 1,
                    name: "frame_send".into(),
                    key: "1->0".into(),
                    value: 2,
                },
            ],
            hists: vec![
                HistRec {
                    rank: 0,
                    name: "wait_ns".into(),
                    hist: h0,
                },
                HistRec {
                    rank: 1,
                    name: "wait_ns".into(),
                    hist: h1,
                },
            ],
            ranks: [0usize, 1].into_iter().collect(),
            complete: true,
            lines: 10,
        }
    }

    #[test]
    fn spans_merge_ranks_and_sort_by_total() {
        let rows = spans(&sample());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "mix");
        assert!(rows[0].virt);
        assert_eq!(rows[1].name, "round");
        assert_eq!(rows[1].count, 2);
        assert_eq!(rows[1].total_ns, 4_000);
        assert!((rows[1].mean_ns() - 2_000.0).abs() < 1e-9);
        assert_eq!(rows[0].clock(), "virtual");
        assert_eq!(rows[1].clock(), "wall");
    }

    #[test]
    fn counters_sum_across_ranks_per_key() {
        let tf = sample();
        let rows = counters(&tf);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].key, "0->1");
        assert_eq!(rows[0].value, 12); // 7 + 5 across ranks
        assert_eq!(rows[1].key, "1->0");
        assert_eq!(rows[1].value, 2);
        let totals = counter_totals(&tf);
        assert_eq!(totals, vec![("frame_send".to_string(), 14)]);
    }

    #[test]
    fn hists_absorb_across_ranks_with_quantiles() {
        let rows = hists(&sample());
        assert_eq!(rows.len(), 1);
        let h = &rows[0];
        assert_eq!(h.name, "wait_ns");
        assert_eq!(h.hist.count, 10);
        // 9 of 10 values in the 64..128 bucket, one outlier
        assert_eq!(h.p50(), 128);
        assert_eq!(h.p90(), 128);
        assert_eq!(h.p99(), 1 << 21);
    }
}
