//! Zero-dependency tracing & telemetry across engines, fabric, and
//! transports (trace schema `lmdfl-trace-v1`).
//!
//! One process-global handle, off by default and compiled down to a
//! single relaxed atomic load per probe when disabled. Enable it via
//! the `observe:` config section (`trace_path` / `chrome_path`) or the
//! `--trace-out` / `--chrome-out` CLI flags; every layer is already
//! instrumented:
//!
//! * **scoped wall spans** ([`span`]) — engine round phases (`round`,
//!   `train`, `quantize`, `mix`, `eval`) and the multi-process node
//!   runtime;
//! * **virtual spans** ([`vspan`]) — simnet/agossip state machines,
//!   timestamped in virtual nanoseconds with one lane per node;
//! * **counters** ([`counter`]) — per-link send/recv/drop/tombstone
//!   frames, TCP reconnects, forced mixes, encoded bytes by quantizer
//!   tag; adversarial scenarios add `byzantine_msgs` (corrupted
//!   broadcasts, keyed by attack name — `sign_flip`, `scale`,
//!   `random`) and `trimmed_drops` (neighbor contributions discarded
//!   by robust mixing, keyed by runtime — `sync`, `async`, `net`);
//! * **histograms** ([`hist`]) — TCP backoff waits, quorum fill
//!   latencies, straggler waits (log2 buckets, see
//!   [`trace::Hist`]).
//!
//! Everything is buffered in memory and written at [`stop`]: a JSONL
//! sink (one typed record per line, parseable by
//! [`export::parse_trace`] and summarized by `lmdfl trace`) and/or a
//! Chrome `trace_event` JSON that opens directly in `about:tracing` or
//! [Perfetto](https://ui.perfetto.dev).
//!
//! Recording only observes engine state — no rng draws, no event
//! reordering, no wall-clock feeding simulated quantities — so traced
//! simnet runs produce byte-identical event digests and RunLogs
//! (enforced by `rust/tests/simnet_determinism.rs`).

pub mod aggregate;
pub mod export;
pub mod summary;
pub mod trace;

pub use trace::{Hist, SpanRec};

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::json::Json;
use crate::config::ConfigError;
use trace::Recorder;

/// Schema identifier written into (and required from) every trace
/// file. Any change to line types or required fields must bump this.
pub const TRACE_SCHEMA: &str = "lmdfl-trace-v1";

/// The `observe:` config section: where to write traces. At least one
/// sink must be set for the section to be meaningful.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ObserveConfig {
    /// JSONL trace sink (schema [`TRACE_SCHEMA`])
    pub trace_path: Option<String>,
    /// Chrome `trace_event` export (about:tracing / Perfetto)
    pub chrome_path: Option<String>,
}

impl ObserveConfig {
    pub fn enabled(&self) -> bool {
        self.trace_path.is_some() || self.chrome_path.is_some()
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if !self.enabled() {
            return Err(ConfigError(
                "observe: needs trace_path and/or chrome_path".into(),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(p) = &self.trace_path {
            pairs.push(("trace_path", Json::str(p)));
        }
        if let Some(p) = &self.chrome_path {
            pairs.push(("chrome_path", Json::str(p)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        Ok(ObserveConfig {
            trace_path: j.get_str("trace_path").map(str::to_string),
            chrome_path: j.get_str("chrome_path").map(str::to_string),
        })
    }
}

// The global handle: a fast-path flag + the mutex-held buffer. Probes
// check ACTIVE first (one relaxed load when tracing is off — well
// inside every bench-smoke gate) and only then take the short lock.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static RECORDER: Mutex<Option<Recorder>> = Mutex::new(None);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static TID: std::cell::Cell<u32> =
        const { std::cell::Cell::new(u32::MAX) };
}

/// Stable small id for the calling thread (allocated on first use).
fn tid() -> u32 {
    TID.with(|t| {
        let v = t.get();
        if v != u32::MAX {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

fn recorder() -> std::sync::MutexGuard<'static, Option<Recorder>> {
    // a panic inside a probe must not poison tracing for the process
    RECORDER.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is tracing enabled? One relaxed atomic load — safe to call on any
/// hot path; guard `format!`-built keys behind it.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Install a fresh recorder and start tracing. `rank` stamps every
/// record (0 for single-process runs).
pub fn start(cfg: &ObserveConfig, rank: usize) {
    let mut rec = recorder();
    *rec = Some(Recorder::new(cfg, rank));
    drop(rec);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Stop tracing and flush every configured sink. Returns the paths
/// written; no-op (empty) if tracing was never started.
pub fn stop() -> anyhow::Result<Vec<String>> {
    ACTIVE.store(false, Ordering::SeqCst);
    let rec = recorder().take();
    match rec {
        Some(r) => export::write(&r),
        None => Ok(Vec::new()),
    }
}

/// Scoped wall-clock span: records `name` with the elapsed time on
/// drop. Free when tracing is disabled (no `Instant::now` call).
pub struct Span {
    name: &'static str,
    started: Option<Instant>,
}

#[must_use = "a span records on drop; bind it to a local"]
pub fn span(name: &'static str) -> Span {
    let started = active().then(Instant::now);
    Span { name, started }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(started) = self.started else { return };
        let dur_ns = started.elapsed().as_nanos() as u64;
        let t = tid();
        if let Some(rec) = recorder().as_mut() {
            rec.wall_span(self.name, t, started, dur_ns);
        }
    }
}

/// Record a span on the *virtual* clock (simnet nanoseconds), one
/// Chrome lane per node. The interval is known to the caller — simnet
/// schedules completions ahead of time — so there is no guard object.
pub fn vspan(name: &'static str, node: usize, start_ns: u64, end_ns: u64) {
    if !active() {
        return;
    }
    if let Some(rec) = recorder().as_mut() {
        rec.virt_span(name, node as u32, start_ns, end_ns);
    }
}

/// Bump the monotonic counter `name[key]` by `n`.
pub fn counter(name: &'static str, key: &str, n: u64) {
    if !active() {
        return;
    }
    if let Some(rec) = recorder().as_mut() {
        rec.counter(name, key, n);
    }
}

/// Record one value into the log2-bucket histogram `name`.
pub fn hist(name: &'static str, v: u64) {
    if !active() {
        return;
    }
    if let Some(rec) = recorder().as_mut() {
        rec.hist(name, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // the handle is process-global: serialize the tests that own it
    static GATE: Mutex<()> = Mutex::new(());

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("lmdfl_obs_{}_{name}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn disabled_probes_are_noops() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!active());
        let s = span("obs-noop");
        drop(s);
        vspan("obs-noop", 0, 0, 10);
        counter("obs-noop", "k", 1);
        hist("obs-noop", 7);
        assert!(recorder().is_none());
        // stop without start writes nothing
        assert!(stop().unwrap().is_empty());
    }

    #[test]
    fn start_record_stop_roundtrips_through_jsonl() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let path = tmp("roundtrip.jsonl");
        let cfg = ObserveConfig {
            trace_path: Some(path.clone()),
            chrome_path: None,
        };
        cfg.validate().unwrap();
        start(&cfg, 3);
        {
            let _s = span("obs-test-wall-span");
        }
        vspan("obs-test-virt-span", 5, 1_000, 4_000);
        counter("obs-test-ctr", "0->1", 2);
        counter("obs-test-ctr", "0->1", 3);
        hist("obs-test-hist", 4096);
        let written = stop().unwrap();
        assert_eq!(written, vec![path.clone()]);
        assert!(!active());

        let text = std::fs::read_to_string(&path).unwrap();
        let tf = export::parse_trace(&text).unwrap();
        assert_eq!(tf.schema, TRACE_SCHEMA);
        assert!(tf.complete);
        assert!(tf.ranks.contains(&3));
        // other concurrently-running tests may also have recorded;
        // assert on the uniquely-named records only
        let wall = tf
            .spans
            .iter()
            .find(|s| s.name == "obs-test-wall-span")
            .unwrap();
        assert!(!wall.virt);
        assert_eq!(wall.rank, 3);
        let virt = tf
            .spans
            .iter()
            .find(|s| s.name == "obs-test-virt-span")
            .unwrap();
        assert!(virt.virt);
        assert_eq!(virt.tid, 5);
        assert_eq!(virt.ts_ns, 1_000);
        assert_eq!(virt.dur_ns, 3_000);
        let ctr = tf
            .counters
            .iter()
            .find(|c| c.name == "obs-test-ctr")
            .unwrap();
        assert_eq!(ctr.key, "0->1");
        assert_eq!(ctr.value, 5);
        let h = tf
            .hists
            .iter()
            .find(|h| h.name == "obs-test-hist")
            .unwrap();
        assert_eq!(h.count, 1);
        assert_eq!(h.sum, 4096);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn observe_config_json_forms() {
        let oc = ObserveConfig {
            trace_path: Some("/tmp/t.jsonl".into()),
            chrome_path: Some("/tmp/t.trace.json".into()),
        };
        let back =
            ObserveConfig::from_json(&oc.to_json()).unwrap();
        assert_eq!(back, oc);
        // empty section is rejected
        assert!(ObserveConfig::default().validate().is_err());
        // one-sink forms are fine and omit the absent key
        let one = ObserveConfig {
            trace_path: Some("x".into()),
            chrome_path: None,
        };
        one.validate().unwrap();
        assert!(!one.to_json().to_string().contains("chrome_path"));
        assert_eq!(
            ObserveConfig::from_json(&one.to_json()).unwrap(),
            one
        );
    }
}
