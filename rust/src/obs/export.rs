//! Trace sinks: the JSONL format (schema `lmdfl-trace-v1`), its
//! parser, per-rank merge, and the Chrome `trace_event` exporter.
//!
//! ## JSONL schema (`lmdfl-trace-v1`)
//!
//! One JSON object per line; the first line is the `meta` record and
//! the last is the `end` footer (its presence marks a complete write —
//! the multi-process merge polls for it). Every record carries the
//! writing process's `rank`:
//!
//! ```text
//! {"type":"meta","schema":"lmdfl-trace-v1","rank":0}
//! {"type":"span","rank":0,"name":"round","clock":"wall",
//!  "tid":0,"ts_ns":1200,"dur_ns":88000}
//! {"type":"ctr","rank":0,"name":"frame_send","key":"0->1","value":12}
//! {"type":"hist","rank":0,"name":"tcp_backoff_ns","count":3,
//!  "sum":900,"buckets":[0,1,2]}
//! {"type":"end","rank":0}
//! ```
//!
//! Readers must reject unknown `type`s and a mismatched `schema` —
//! additions bump [`TRACE_SCHEMA`](super::TRACE_SCHEMA).
//!
//! ## Chrome export
//!
//! [`chrome_trace`] emits `about:tracing` / Perfetto duration events:
//! wall spans on pid `2*rank`, virtual spans on pid `2*rank + 1` with
//! one tid lane per node, `ts` in microseconds. Overlapping same-lane
//! spans are legal input: each span's end is clamped to its stack
//! parent's end, which keeps the B/E stream balanced and its
//! timestamps non-decreasing for *arbitrary* span sets (property-
//! tested in `util::proptest`).

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;

use super::trace::{Hist, Recorder, SpanRec};
use crate::config::json::Json;

/// One counter line re-read from a trace file.
#[derive(Clone, Debug, PartialEq)]
pub struct CtrRec {
    pub rank: usize,
    pub name: String,
    pub key: String,
    pub value: u64,
}

/// One histogram line re-read from a trace file.
#[derive(Clone, Debug, PartialEq)]
pub struct HistRec {
    pub rank: usize,
    pub name: String,
    pub hist: Hist,
}

/// A parsed trace file (possibly merged across ranks).
#[derive(Clone, Debug, Default)]
pub struct TraceFile {
    pub schema: String,
    pub spans: Vec<SpanRec>,
    pub counters: Vec<CtrRec>,
    pub hists: Vec<HistRec>,
    pub ranks: BTreeSet<usize>,
    /// an `end` footer was present (complete write)
    pub complete: bool,
    pub lines: usize,
}

/// Flush a recorder to every configured sink; returns paths written.
pub(crate) fn write(rec: &Recorder) -> anyhow::Result<Vec<String>> {
    let mut written = Vec::new();
    if let Some(p) = &rec.trace_path {
        write_jsonl(rec, p)
            .map_err(|e| anyhow::anyhow!("writing trace {p}: {e}"))?;
        written.push(p.clone());
    }
    if let Some(p) = &rec.chrome_path {
        let text = chrome_trace(&chrome_spans(&rec.spans));
        std::fs::write(p, text)
            .map_err(|e| anyhow::anyhow!("writing chrome {p}: {e}"))?;
        written.push(p.clone());
    }
    Ok(written)
}

fn write_jsonl(rec: &Recorder, path: &str) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{}", meta_line(rec.rank).to_string())?;
    for s in &rec.spans {
        writeln!(w, "{}", span_line(s).to_string())?;
    }
    for ((name, key), value) in &rec.counters {
        let j = Json::obj(vec![
            ("type", Json::str("ctr")),
            ("rank", Json::num(rec.rank as f64)),
            ("name", Json::str(name)),
            ("key", Json::str(key)),
            ("value", Json::num(*value as f64)),
        ]);
        writeln!(w, "{}", j.to_string())?;
    }
    for (name, h) in &rec.hists {
        let j = Json::obj(vec![
            ("type", Json::str("hist")),
            ("rank", Json::num(rec.rank as f64)),
            ("name", Json::str(name)),
            ("count", Json::num(h.count as f64)),
            ("sum", Json::num(h.sum as f64)),
            (
                "buckets",
                Json::Arr(
                    h.buckets
                        .iter()
                        .map(|&n| Json::num(n as f64))
                        .collect(),
                ),
            ),
        ]);
        writeln!(w, "{}", j.to_string())?;
    }
    writeln!(w, "{}", end_line(rec.rank).to_string())?;
    w.flush()
}

fn meta_line(rank: usize) -> Json {
    Json::obj(vec![
        ("type", Json::str("meta")),
        ("schema", Json::str(super::TRACE_SCHEMA)),
        ("rank", Json::num(rank as f64)),
    ])
}

fn end_line(rank: usize) -> Json {
    Json::obj(vec![
        ("type", Json::str("end")),
        ("rank", Json::num(rank as f64)),
    ])
}

fn span_line(s: &SpanRec) -> Json {
    Json::obj(vec![
        ("type", Json::str("span")),
        ("rank", Json::num(s.rank as f64)),
        ("name", Json::str(&s.name)),
        (
            "clock",
            Json::str(if s.virt { "virtual" } else { "wall" }),
        ),
        ("tid", Json::num(s.tid as f64)),
        ("ts_ns", Json::num(s.ts_ns as f64)),
        ("dur_ns", Json::num(s.dur_ns as f64)),
    ])
}

/// Parse a JSONL trace (strict: unknown line types and a missing or
/// mismatched schema are errors; the first line must be `meta`).
pub fn parse_trace(text: &str) -> anyhow::Result<TraceFile> {
    let mut tf = TraceFile::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let n = i + 1;
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {n}: {e}"))?;
        let typ = j
            .get_str("type")
            .ok_or_else(|| anyhow::anyhow!("trace line {n}: no type"))?;
        let rank = j.get_usize("rank").unwrap_or(0);
        if typ != "meta" && tf.schema.is_empty() {
            anyhow::bail!("trace line {n}: file must start with meta");
        }
        match typ {
            "meta" => {
                let schema = j.get_str("schema").ok_or_else(|| {
                    anyhow::anyhow!("trace line {n}: meta without schema")
                })?;
                if tf.schema.is_empty() {
                    tf.schema = schema.to_string();
                } else if tf.schema != schema {
                    anyhow::bail!(
                        "trace line {n}: mixed schemas \
                         '{}' and '{schema}'",
                        tf.schema
                    );
                }
            }
            "span" => {
                let get = |k: &str| {
                    j.get_f64(k).ok_or_else(|| {
                        anyhow::anyhow!("trace line {n}: span missing {k}")
                    })
                };
                tf.ranks.insert(rank);
                tf.spans.push(SpanRec {
                    rank,
                    name: j
                        .get_str("name")
                        .unwrap_or_default()
                        .to_string(),
                    virt: j.get_str("clock") == Some("virtual"),
                    tid: get("tid")? as u32,
                    ts_ns: get("ts_ns")? as u64,
                    dur_ns: get("dur_ns")? as u64,
                });
            }
            "ctr" => {
                tf.ranks.insert(rank);
                tf.counters.push(CtrRec {
                    rank,
                    name: j
                        .get_str("name")
                        .unwrap_or_default()
                        .to_string(),
                    key: j.get_str("key").unwrap_or_default().to_string(),
                    value: j.get_f64("value").unwrap_or(0.0) as u64,
                });
            }
            "hist" => {
                tf.ranks.insert(rank);
                let buckets = j
                    .get("buckets")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .map(|v| v.as_f64().unwrap_or(0.0) as u64)
                            .collect()
                    })
                    .unwrap_or_default();
                tf.hists.push(HistRec {
                    rank,
                    name: j
                        .get_str("name")
                        .unwrap_or_default()
                        .to_string(),
                    hist: Hist {
                        count: j.get_f64("count").unwrap_or(0.0) as u64,
                        sum: j.get_f64("sum").unwrap_or(0.0) as u64,
                        buckets,
                    },
                });
            }
            "end" => tf.complete = true,
            other => anyhow::bail!(
                "trace line {n}: unknown record type '{other}' \
                 (schema {})",
                super::TRACE_SCHEMA
            ),
        }
        tf.lines += 1;
    }
    if tf.schema.is_empty() {
        anyhow::bail!("empty trace: no meta line");
    }
    Ok(tf)
}

/// The per-rank trace path of a multi-process run: rank `r` writes
/// `<stem>.rank<r>.jsonl` and rank 0 merges them into the base path.
pub fn rank_path(base: &str, rank: usize) -> String {
    match base.strip_suffix(".jsonl") {
        Some(stem) => format!("{stem}.rank{rank}.jsonl"),
        None => format!("{base}.rank{rank}"),
    }
}

fn file_complete(path: &str) -> bool {
    let Ok(text) = std::fs::read_to_string(path) else {
        return false;
    };
    let Some(last) = text.lines().rev().find(|l| !l.trim().is_empty())
    else {
        return false;
    };
    matches!(Json::parse(last), Ok(j) if j.get_str("type") == Some("end"))
}

/// Merge the per-rank trace files of an `nodes`-process run into
/// `base`, polling up to `wait` for stragglers' end footers. Per-rank
/// meta/end lines are dropped (every record already carries its rank)
/// and a fresh meta/end pair frames the merged file. Returns a human
/// summary; missing ranks are merged best-effort and reported.
pub fn merge_ranks(
    base: &str,
    nodes: usize,
    wait: std::time::Duration,
) -> anyhow::Result<String> {
    let deadline = std::time::Instant::now() + wait;
    let paths: Vec<String> =
        (0..nodes).map(|r| rank_path(base, r)).collect();
    while paths.iter().any(|p| !file_complete(p))
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let f = std::fs::File::create(base)
        .map_err(|e| anyhow::anyhow!("creating {base}: {e}"))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{}", meta_line(0).to_string())?;
    let mut merged = 0usize;
    for p in &paths {
        let Ok(text) = std::fs::read_to_string(p) else { continue };
        merged += 1;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Ok(j) = Json::parse(line) else { continue };
            match j.get_str("type") {
                Some("meta") | Some("end") => {}
                _ => writeln!(w, "{line}")?,
            }
        }
    }
    writeln!(w, "{}", end_line(0).to_string())?;
    w.flush()?;
    Ok(format!("merged {merged}/{nodes} rank traces into {base}"))
}

// ---- Chrome trace_event export -----------------------------------------

/// A span on one Chrome lane (`pid`, `tid`), nanosecond interval.
#[derive(Clone, Debug, PartialEq)]
pub struct ChromeSpan {
    pub pid: u32,
    pub tid: u32,
    pub name: String,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

impl ChromeSpan {
    fn end(&self) -> u64 {
        self.ts_ns.saturating_add(self.dur_ns)
    }
}

/// One emitted duration event (`ph: B` or `ph: E`).
#[derive(Clone, Debug, PartialEq)]
pub struct ChromeEvent {
    pub begin: bool,
    pub pid: u32,
    pub tid: u32,
    pub name: String,
    pub ts_ns: u64,
}

/// Map recorded spans onto Chrome lanes: wall clock on pid `2*rank`
/// (tid = recording thread), virtual clock on pid `2*rank + 1`
/// (tid = node id).
pub fn chrome_spans(spans: &[SpanRec]) -> Vec<ChromeSpan> {
    spans
        .iter()
        .map(|s| ChromeSpan {
            pid: (s.rank as u32) * 2 + u32::from(s.virt),
            tid: s.tid,
            name: s.name.clone(),
            ts_ns: s.ts_ns,
            dur_ns: s.dur_ns,
        })
        .collect()
}

/// Lower spans to a balanced `B`/`E` event stream, per (pid, tid)
/// lane. Chrome's duration events are strictly stack-shaped; spans
/// that only partially overlap a same-lane predecessor are clamped to
/// their stack parent's end, so for *arbitrary* input the stream keeps
/// both exporter invariants: per-lane timestamps never decrease, and
/// every `B` has exactly one matching `E`.
pub fn chrome_events(spans: &[ChromeSpan]) -> Vec<ChromeEvent> {
    let mut lanes: BTreeMap<(u32, u32), Vec<&ChromeSpan>> =
        BTreeMap::new();
    for s in spans {
        lanes.entry((s.pid, s.tid)).or_default().push(s);
    }
    let mut out = Vec::with_capacity(spans.len() * 2);
    for ((pid, tid), mut lane) in lanes {
        // by start; longer span first on ties so it becomes the parent
        lane.sort_by_key(|s| (s.ts_ns, std::cmp::Reverse(s.end())));
        let mut stack: Vec<(String, u64)> = Vec::new();
        let pop = |stack: &mut Vec<(String, u64)>,
                       out: &mut Vec<ChromeEvent>| {
            let (name, end) = stack.pop().expect("non-empty stack");
            out.push(ChromeEvent {
                begin: false,
                pid,
                tid,
                name,
                ts_ns: end,
            });
        };
        for s in lane {
            while matches!(stack.last(), Some((_, end)) if *end <= s.ts_ns)
            {
                pop(&mut stack, &mut out);
            }
            // clamp to the parent: stack ends stay nested (the top is
            // the minimum), which is what makes pops non-decreasing
            let mut end = s.end();
            if let Some((_, parent_end)) = stack.last() {
                end = end.min(*parent_end);
            }
            out.push(ChromeEvent {
                begin: true,
                pid,
                tid,
                name: s.name.clone(),
                ts_ns: s.ts_ns,
            });
            stack.push((s.name.clone(), end));
        }
        while !stack.is_empty() {
            pop(&mut stack, &mut out);
        }
    }
    out
}

/// Render spans as a Chrome `trace_event` JSON document (`ts` in
/// microseconds, as the format requires).
pub fn chrome_trace(spans: &[ChromeSpan]) -> String {
    let events: Vec<Json> = chrome_events(spans)
        .into_iter()
        .map(|e| {
            Json::obj(vec![
                ("ph", Json::str(if e.begin { "B" } else { "E" })),
                ("pid", Json::num(e.pid as f64)),
                ("tid", Json::num(e.tid as f64)),
                ("name", Json::str(&e.name)),
                ("cat", Json::str("lmdfl")),
                ("ts", Json::num(e.ts_ns as f64 / 1e3)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cs(tid: u32, ts: u64, dur: u64, name: &str) -> ChromeSpan {
        ChromeSpan {
            pid: 0,
            tid,
            name: name.to_string(),
            ts_ns: ts,
            dur_ns: dur,
        }
    }

    #[test]
    fn nested_spans_emit_stack_shaped_events() {
        let spans =
            vec![cs(1, 0, 100, "outer"), cs(1, 10, 20, "inner")];
        let ev = chrome_events(&spans);
        let shape: Vec<(bool, &str, u64)> = ev
            .iter()
            .map(|e| (e.begin, e.name.as_str(), e.ts_ns))
            .collect();
        assert_eq!(
            shape,
            vec![
                (true, "outer", 0),
                (true, "inner", 10),
                (false, "inner", 30),
                (false, "outer", 100),
            ]
        );
    }

    #[test]
    fn partial_overlap_is_clamped_not_unbalanced() {
        // a=[0,10), b=[5,15): naive emission would close a at 10 AFTER
        // closing b at 15 — decreasing timestamps; the exporter clamps
        // b to its parent's end instead
        let spans = vec![cs(0, 0, 10, "a"), cs(0, 5, 10, "b")];
        let ev = chrome_events(&spans);
        let mut last = 0;
        let mut depth = 0i64;
        for e in &ev {
            assert!(e.ts_ns >= last, "ts decreased");
            last = e.ts_ns;
            depth += if e.begin { 1 } else { -1 };
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0);
        assert_eq!(ev.len(), 4);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let spans = vec![cs(0, 0, 1000, "x"), cs(1, 500, 800, "y")];
        let doc = Json::parse(&chrome_trace(&spans)).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get_str("ph"), Some("B"));
        // ns -> µs
        assert_eq!(events[0].get_f64("ts"), Some(0.0));
        assert!(events
            .iter()
            .any(|e| e.get_f64("ts") == Some(0.5)));
    }

    #[test]
    fn parse_rejects_bad_traces() {
        // no meta
        assert!(parse_trace("").is_err());
        assert!(parse_trace(
            "{\"type\":\"span\",\"rank\":0,\"name\":\"x\",\
             \"clock\":\"wall\",\"tid\":0,\"ts_ns\":0,\"dur_ns\":1}"
        )
        .is_err());
        // unknown type
        let text = format!(
            "{}\n{{\"type\":\"wat\"}}\n",
            "{\"type\":\"meta\",\"schema\":\"lmdfl-trace-v1\",\
             \"rank\":0}"
        );
        assert!(parse_trace(&text).is_err());
        // minimal complete file parses
        let ok = "{\"type\":\"meta\",\"schema\":\"lmdfl-trace-v1\",\
                  \"rank\":0}\n{\"type\":\"end\",\"rank\":0}\n";
        let tf = parse_trace(ok).unwrap();
        assert!(tf.complete);
        assert_eq!(tf.lines, 2);
    }

    #[test]
    fn rank_paths_and_merge() {
        assert_eq!(
            rank_path("/tmp/t.jsonl", 2),
            "/tmp/t.rank2.jsonl"
        );
        assert_eq!(rank_path("/tmp/t", 2), "/tmp/t.rank2");
        let dir = std::env::temp_dir();
        let base = dir
            .join(format!("lmdfl_merge_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        for r in 0..2usize {
            let mut body = format!(
                "{{\"type\":\"meta\",\
                 \"schema\":\"lmdfl-trace-v1\",\"rank\":{r}}}\n"
            );
            body.push_str(&format!(
                "{{\"type\":\"ctr\",\"rank\":{r},\
                 \"name\":\"n\",\"key\":\"k\",\"value\":{r}}}\n\
                 {{\"type\":\"end\",\"rank\":{r}}}\n"
            ));
            std::fs::write(rank_path(&base, r), body).unwrap();
        }
        let msg = merge_ranks(
            &base,
            2,
            std::time::Duration::from_secs(2),
        )
        .unwrap();
        assert!(msg.contains("2/2"));
        let tf =
            parse_trace(&std::fs::read_to_string(&base).unwrap())
                .unwrap();
        assert!(tf.complete);
        assert_eq!(tf.counters.len(), 2);
        assert_eq!(tf.ranks.len(), 2);
        for r in 0..2usize {
            let _ = std::fs::remove_file(rank_path(&base, r));
        }
        let _ = std::fs::remove_file(&base);
    }
}
