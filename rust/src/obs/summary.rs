//! `lmdfl trace`: schema validation and a human summary of a trace
//! file — top spans by total time, counter tables (per-link bytes,
//! drops, reconnects), and histogram digests — rendered with the
//! existing [`crate::metrics::Table`]. All rollups come from
//! [`super::aggregate`], the same code `lmdfl analyse` builds its
//! sweep CSVs from, so the two views can never drift.

use super::aggregate;
use super::export::TraceFile;
use crate::metrics::Table;

/// Validate a parsed trace against the current schema: version match,
/// complete end footer, and at least one record. Returns a one-line
/// OK summary (CI prints it).
pub fn check(tf: &TraceFile) -> anyhow::Result<String> {
    if tf.schema != super::TRACE_SCHEMA {
        anyhow::bail!(
            "trace schema '{}' != expected '{}'",
            tf.schema,
            super::TRACE_SCHEMA
        );
    }
    if !tf.complete {
        anyhow::bail!("trace has no end footer (truncated write?)");
    }
    if tf.spans.is_empty() && tf.counters.is_empty() {
        anyhow::bail!("trace carries no spans and no counters");
    }
    Ok(format!(
        "trace OK: schema {}, {} lines, {} spans, {} counters, \
         {} histograms, {} rank(s)",
        tf.schema,
        tf.lines,
        tf.spans.len(),
        tf.counters.len(),
        tf.hists.len(),
        tf.ranks.len().max(1),
    ))
}

/// Render the full human summary of a parsed trace.
pub fn summarize(tf: &TraceFile) -> String {
    let mut out = format!(
        "trace: schema {}, {} spans, {} counters, {} histograms, \
         ranks {:?}{}\n",
        tf.schema,
        tf.spans.len(),
        tf.counters.len(),
        tf.hists.len(),
        tf.ranks.iter().collect::<Vec<_>>(),
        if tf.complete { "" } else { " [INCOMPLETE]" },
    );
    if !tf.spans.is_empty() {
        out.push_str("\ntop spans by total time\n");
        out.push_str(&span_table(tf));
    }
    if !tf.counters.is_empty() {
        out.push_str("\ncounters (ranks merged)\n");
        out.push_str(&aggregate_counter_table(tf));
        out.push_str("\ncounters by rank\n");
        out.push_str(&counter_table(tf));
    }
    if !tf.hists.is_empty() {
        out.push_str("\nhistograms (ranks merged)\n");
        out.push_str(&hist_table(tf));
    }
    out
}

/// Spans aggregated by (name, clock), top 12 by total duration.
fn span_table(tf: &TraceFile) -> String {
    let mut t =
        Table::new(&["span", "clock", "count", "total ms", "mean µs"]);
    for a in aggregate::spans(tf).into_iter().take(12) {
        t.row(vec![
            a.name.clone(),
            a.clock().into(),
            format!("{}", a.count),
            format!("{:.3}", a.total_ns as f64 / 1e6),
            format!("{:.1}", a.mean_ns() / 1e3),
        ]);
    }
    t.render()
}

/// Per-(name, key) values summed across every rank — the sweep-grade
/// aggregate view — plus per-name totals.
fn aggregate_counter_table(tf: &TraceFile) -> String {
    let mut t = Table::new(&["counter", "key", "value"]);
    for (name, total) in aggregate::counter_totals(tf) {
        t.row(vec![name, "(total)".into(), format!("{total}")]);
    }
    let rows = aggregate::counters(tf);
    let cap = 40usize;
    for c in rows.iter().take(cap) {
        t.row(vec![
            c.name.clone(),
            c.key.clone(),
            format!("{}", c.value),
        ]);
    }
    let mut out = t.render();
    if rows.len() > cap {
        out.push_str(&format!(
            "(+{} more aggregate rows)\n",
            rows.len() - cap
        ));
    }
    out
}

/// The largest per-rank/per-key rows (per-link byte and drop tables
/// live here); totals live in the rank-merged table above.
fn counter_table(tf: &TraceFile) -> String {
    let mut t = Table::new(&["counter", "rank", "key", "value"]);
    let mut rows: Vec<_> = tf.counters.iter().collect();
    rows.sort_by(|a, b| {
        (&a.name, std::cmp::Reverse(a.value), a.rank, &a.key).cmp(&(
            &b.name,
            std::cmp::Reverse(b.value),
            b.rank,
            &b.key,
        ))
    });
    let cap = 40usize;
    for c in rows.iter().take(cap) {
        t.row(vec![
            c.name.clone(),
            format!("{}", c.rank),
            c.key.clone(),
            format!("{}", c.value),
        ]);
    }
    let mut out = t.render();
    if rows.len() > cap {
        out.push_str(&format!(
            "(+{} more counter rows)\n",
            rows.len() - cap
        ));
    }
    out
}

/// Histograms merged across ranks: count, mean, and p50/p90/p99
/// bucket upper edges (values are nanoseconds by convention).
fn hist_table(tf: &TraceFile) -> String {
    let mut t = Table::new(&[
        "histogram",
        "count",
        "mean µs",
        "p50 ≤ µs",
        "p90 ≤ µs",
        "p99 ≤ µs",
    ]);
    for a in aggregate::hists(tf) {
        t.row(vec![
            a.name.clone(),
            format!("{}", a.hist.count),
            format!("{:.1}", a.hist.mean() / 1e3),
            format!("{:.1}", a.p50() as f64 / 1e3),
            format!("{:.1}", a.p90() as f64 / 1e3),
            format!("{:.1}", a.p99() as f64 / 1e3),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::{CtrRec, HistRec};
    use crate::obs::{Hist, SpanRec};

    fn sample() -> TraceFile {
        let mut h = Hist::default();
        h.record(1_000);
        h.record(2_000);
        TraceFile {
            schema: crate::obs::TRACE_SCHEMA.to_string(),
            spans: vec![SpanRec {
                rank: 0,
                name: "round".into(),
                virt: false,
                tid: 0,
                ts_ns: 0,
                dur_ns: 2_000_000,
            }],
            counters: vec![
                CtrRec {
                    rank: 0,
                    name: "frame_send".into(),
                    key: "0->1".into(),
                    value: 7,
                },
                CtrRec {
                    rank: 1,
                    name: "frame_send".into(),
                    key: "1->0".into(),
                    value: 5,
                },
            ],
            hists: vec![HistRec {
                rank: 0,
                name: "tcp_backoff_ns".into(),
                hist: h,
            }],
            ranks: [0usize, 1].into_iter().collect(),
            complete: true,
            lines: 6,
        }
    }

    #[test]
    fn check_accepts_good_and_rejects_bad() {
        let tf = sample();
        assert!(check(&tf).unwrap().contains("trace OK"));
        let mut bad = tf.clone();
        bad.schema = "lmdfl-trace-v0".into();
        assert!(check(&bad).is_err());
        let mut bad = tf.clone();
        bad.complete = false;
        assert!(check(&bad).is_err());
        let mut bad = tf;
        bad.spans.clear();
        bad.counters.clear();
        assert!(check(&bad).is_err());
    }

    #[test]
    fn summary_carries_all_sections() {
        let s = summarize(&sample());
        assert!(s.contains("top spans"));
        assert!(s.contains("round"));
        assert!(s.contains("counters (ranks merged)"));
        assert!(s.contains("counters by rank"));
        assert!(s.contains("frame_send"));
        assert!(s.contains("(total)"));
        assert!(s.contains("12")); // 7 + 5 total
        assert!(s.contains("histograms (ranks merged)"));
        assert!(s.contains("p90"));
        assert!(s.contains("tcp_backoff_ns"));
    }
}
