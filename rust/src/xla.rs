//! Inert stand-in for the `xla` / PJRT bindings.
//!
//! The production design executes AOT-lowered HLO artifacts through the
//! `xla` crate (PJRT C API bindings); that crate needs a multi-gigabyte
//! native `xla_extension` toolchain which is not available in this build
//! environment. This module keeps the exact API surface [`crate::runtime`]
//! and the HLO examples/tests compile against, with runtime behaviour:
//!
//! * [`Literal`] is fully functional (shape-checked host tensors), so the
//!   literal-construction helpers and their tests work unchanged.
//! * [`PjRtClient::cpu`] returns an error, so every execution path fails
//!   fast with a clear message instead of at link time. All HLO tests are
//!   gated on `artifacts_available()` and skip cleanly.
//!
//! When a real PJRT toolchain is present, replace this module with
//! `pub use xla::*;` of the real crate behind a cargo feature; no call
//! sites need to change.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' error enum (callers format it
/// with `{:?}`).
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA bindings are not available in this build; use \
         the pure-Rust backend (backend.kind = \"rust_mlp\") or install \
         the xla_extension toolchain"
    ))
}

/// Element types a [`Literal`] can hold.
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }
}

/// Conversion between Rust scalars and literal storage (sealed-enough:
/// only f32/i32 are used by the runtime).
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> LiteralData {
        LiteralData::F32(data.to_vec())
    }

    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            LiteralData::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> LiteralData {
        LiteralData::I32(data.to_vec())
    }

    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            LiteralData::F32(_) => None,
        }
    }
}

/// Host tensor: flat data plus logical dims. Functional (shape-checked)
/// even in this stand-in so literal-building code paths stay testable.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims` (empty = scalar); errors on element-count
    /// mismatch, like the real bindings.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product::<i64>().max(1);
        if want < 0 || want as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Logical dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the flat data out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data)
            .ok_or_else(|| XlaError("literal dtype mismatch".into()))
    }

    /// Flatten a tuple literal — execution never succeeds in this build,
    /// so no tuple literal can exist.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (never constructible here).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(
        path: P,
    ) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!(
            "loading HLO text {}",
            path.as_ref().display()
        )))
    }
}

/// XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. `cpu()` fails in this build, which is the single
/// choke point that keeps every HLO execution path unreachable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Device-resident buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _inputs: &[Literal],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape_checks() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        // scalar reshape of a single element
        let s = Literal::vec1(&[5i32]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
        // dtype mismatch is an error, not a transmute
        assert!(s.to_vec::<f32>().is_err());
    }

    #[test]
    fn execution_paths_fail_fast() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("not available"));
    }
}
