//! Hand-written JSON parser + serializer (no serde offline).
//!
//! Full JSON: objects, arrays, strings (with escapes + \uXXXX), numbers,
//! bools, null. Good error positions. Used for the experiment config files,
//! `artifacts/manifest.json` and the metrics JSON outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| {
            if x.fract() == 0.0 {
                Some(x as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path access: `j.path(&["model", "dims"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    // ---- construction helpers ------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parse / serialize ----------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Inf; serialize as null (metrics use
                    // NaN for "not evaluated this round")
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(
                        out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(
                    out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair support
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(
                                    self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(
                            || self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multi-byte UTF-8 from the source
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#).unwrap();
        assert_eq!(j.path(&["c", "d"]), Some(&Json::Bool(true)));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":null},"z":true}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "f": 1.5, "s": "x"}"#).unwrap();
        assert_eq!(j.get_usize("n"), Some(3));
        assert_eq!(j.get_usize("f"), None);
        assert_eq!(j.get_f64("f"), Some(1.5));
        assert_eq!(j.get_str("s"), Some("x"));
        assert_eq!(j.get_usize("missing"), None);
    }
}
