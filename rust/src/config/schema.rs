//! Typed experiment configuration (parsed from / serialized to JSON).
//!
//! A single [`ExperimentConfig`] drives a DFL run end-to-end: topology,
//! quantizer, dataset, model backend, schedule. `lmdfl train --config x.json`
//! consumes these; every example/bench builds them programmatically.

use crate::config::json::Json;

#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn bad(msg: impl Into<String>) -> ConfigError {
    ConfigError(msg.into())
}

/// Network topology choices (paper Fig. 7 evaluates full/ring/disconnected).
#[derive(Clone, Debug, PartialEq)]
pub enum TopologyKind {
    /// C = J: fully connected uniform averaging (ζ = 0).
    Full,
    /// Ring with uniform self+neighbour weights (paper's ζ≈0.87 at N=10
    /// comes from a ring-like sparse graph).
    Ring,
    /// C = I: no communication (ζ = 1).
    Disconnected,
    /// Erdős–Rényi random graph with Metropolis–Hastings weights.
    Random { p: f64 },
    /// Star around node 0 with Metropolis–Hastings weights.
    Star,
    /// 2D torus grid (rows x cols = N) with Metropolis–Hastings weights.
    Torus,
    /// Random k-regular graph (seeded pairing model) with
    /// Metropolis–Hastings weights — the sparse constant-degree
    /// topology the large-scale presets run on.
    RandomRegular { k: usize },
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Full => "full",
            TopologyKind::Ring => "ring",
            TopologyKind::Disconnected => "disconnected",
            TopologyKind::Random { .. } => "random",
            TopologyKind::Star => "star",
            TopologyKind::Torus => "torus",
            TopologyKind::RandomRegular { .. } => "random_regular",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            TopologyKind::Random { p } => Json::obj(vec![
                ("kind", Json::str("random")),
                ("p", Json::num(*p)),
            ]),
            TopologyKind::RandomRegular { k } => Json::obj(vec![
                ("kind", Json::str("random_regular")),
                ("k", Json::num(*k as f64)),
            ]),
            other => Json::obj(vec![("kind", Json::str(other.name()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get_str("kind")
            .ok_or_else(|| bad("topology.kind missing"))?;
        Ok(match kind {
            "full" => TopologyKind::Full,
            "ring" => TopologyKind::Ring,
            "disconnected" => TopologyKind::Disconnected,
            "star" => TopologyKind::Star,
            "torus" => TopologyKind::Torus,
            "random" => TopologyKind::Random {
                p: j.get_f64("p").unwrap_or(0.4),
            },
            "random_regular" => TopologyKind::RandomRegular {
                k: j.get_f64("k").unwrap_or(4.0) as usize,
            },
            other => return Err(bad(format!("unknown topology '{other}'"))),
        })
    }
}

/// Quantizer choices (paper Table I + baselines of section VI).
#[derive(Clone, Debug, PartialEq)]
pub enum QuantizerKind {
    /// No quantization: full-precision exchange (paper's "DFL without
    /// quantization" baseline; s = 16000 in their setup).
    Full,
    /// QSGD uniform stochastic quantizer [14].
    Qsgd { s: usize },
    /// Natural compression: binary-geometric levels [16].
    Natural { s: usize },
    /// ALQ: adaptive levels via coordinate descent [18].
    Alq { s: usize },
    /// Lloyd-Max quantizer (the paper's LM-DFL).
    LloydMax { s: usize, iters: usize },
    /// Doubly-adaptive: Lloyd-Max levels + ascending level count (Eq. 37).
    DoublyAdaptive { s1: usize, iters: usize, s_max: usize },
    /// TernGrad ternary stochastic quantization [11] (extension
    /// baseline; ships the sparse wire body when it is smaller).
    TernGrad,
    /// Top-k sparsification [12]: keep this fraction of coordinates at
    /// full precision (ships the sparse wire body).
    TopK { keep: f64 },
}

impl QuantizerKind {
    pub fn name(&self) -> &'static str {
        match self {
            QuantizerKind::Full => "full",
            QuantizerKind::Qsgd { .. } => "qsgd",
            QuantizerKind::Natural { .. } => "natural",
            QuantizerKind::Alq { .. } => "alq",
            QuantizerKind::LloydMax { .. } => "lloyd_max",
            QuantizerKind::DoublyAdaptive { .. } => "doubly_adaptive",
            QuantizerKind::TernGrad => "terngrad",
            QuantizerKind::TopK { .. } => "topk",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::str(self.name()))];
        match self {
            QuantizerKind::Full => {}
            QuantizerKind::Qsgd { s }
            | QuantizerKind::Natural { s }
            | QuantizerKind::Alq { s } => {
                pairs.push(("s", Json::num(*s as f64)));
            }
            QuantizerKind::LloydMax { s, iters } => {
                pairs.push(("s", Json::num(*s as f64)));
                pairs.push(("iters", Json::num(*iters as f64)));
            }
            QuantizerKind::DoublyAdaptive { s1, iters, s_max } => {
                pairs.push(("s1", Json::num(*s1 as f64)));
                pairs.push(("iters", Json::num(*iters as f64)));
                pairs.push(("s_max", Json::num(*s_max as f64)));
            }
            QuantizerKind::TernGrad => {}
            QuantizerKind::TopK { keep } => {
                pairs.push(("keep", Json::num(*keep)));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get_str("kind")
            .ok_or_else(|| bad("quantizer.kind missing"))?;
        let s = || j.get_usize("s").unwrap_or(16);
        Ok(match kind {
            "full" => QuantizerKind::Full,
            "qsgd" => QuantizerKind::Qsgd { s: s() },
            "natural" => QuantizerKind::Natural { s: s() },
            "alq" => QuantizerKind::Alq { s: s() },
            "lloyd_max" => QuantizerKind::LloydMax {
                s: s(),
                iters: j.get_usize("iters").unwrap_or(12),
            },
            "doubly_adaptive" => QuantizerKind::DoublyAdaptive {
                s1: j.get_usize("s1").unwrap_or(4),
                iters: j.get_usize("iters").unwrap_or(12),
                s_max: j.get_usize("s_max").unwrap_or(4096),
            },
            "terngrad" => QuantizerKind::TernGrad,
            "topk" => QuantizerKind::TopK {
                keep: j.get_f64("keep").unwrap_or(0.1),
            },
            other => return Err(bad(format!("unknown quantizer '{other}'"))),
        })
    }
}

/// Synthetic dataset choices (§Substitutions in DESIGN.md).
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetKind {
    /// Procedural 28x28 grayscale digit glyphs, 10 classes.
    SynthMnist { train: usize, test: usize },
    /// Procedural 3x32x32 class-conditioned textures, 10 classes.
    SynthCifar { train: usize, test: usize },
    /// Gaussian blobs in `dim` dimensions, `classes` classes.
    Blobs { train: usize, test: usize, dim: usize, classes: usize },
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthMnist { .. } => "synth_mnist",
            DatasetKind::SynthCifar { .. } => "synth_cifar",
            DatasetKind::Blobs { .. } => "blobs",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            DatasetKind::SynthMnist { train, test }
            | DatasetKind::SynthCifar { train, test } => Json::obj(vec![
                ("kind", Json::str(self.name())),
                ("train", Json::num(*train as f64)),
                ("test", Json::num(*test as f64)),
            ]),
            DatasetKind::Blobs { train, test, dim, classes } => Json::obj(vec![
                ("kind", Json::str("blobs")),
                ("train", Json::num(*train as f64)),
                ("test", Json::num(*test as f64)),
                ("dim", Json::num(*dim as f64)),
                ("classes", Json::num(*classes as f64)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get_str("kind")
            .ok_or_else(|| bad("dataset.kind missing"))?;
        let train = j.get_usize("train").unwrap_or(2000);
        let test = j.get_usize("test").unwrap_or(500);
        Ok(match kind {
            "synth_mnist" => DatasetKind::SynthMnist { train, test },
            "synth_cifar" => DatasetKind::SynthCifar { train, test },
            "blobs" => DatasetKind::Blobs {
                train,
                test,
                dim: j.get_usize("dim").unwrap_or(32),
                classes: j.get_usize("classes").unwrap_or(10),
            },
            other => return Err(bad(format!("unknown dataset '{other}'"))),
        })
    }
}

/// Which local-update backend executes the SGD steps.
#[derive(Clone, Debug, PartialEq)]
pub enum BackendKind {
    /// Pure-Rust MLP with hand-derived gradients (fast sweeps).
    RustMlp { hidden: Vec<usize> },
    /// AOT-compiled HLO artifact executed via PJRT (the production path).
    Hlo { artifact: String },
}

impl BackendKind {
    pub fn to_json(&self) -> Json {
        match self {
            BackendKind::RustMlp { hidden } => Json::obj(vec![
                ("kind", Json::str("rust_mlp")),
                ("hidden", Json::arr_usize(hidden)),
            ]),
            BackendKind::Hlo { artifact } => Json::obj(vec![
                ("kind", Json::str("hlo")),
                ("artifact", Json::str(artifact)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get_str("kind")
            .ok_or_else(|| bad("backend.kind missing"))?;
        Ok(match kind {
            "rust_mlp" => {
                let hidden = j
                    .get("hidden")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter().filter_map(Json::as_usize).collect()
                    })
                    .unwrap_or_else(|| vec![64]);
                BackendKind::RustMlp { hidden }
            }
            "hlo" => BackendKind::Hlo {
                artifact: j
                    .get_str("artifact")
                    .ok_or_else(|| bad("backend.artifact missing"))?
                    .to_string(),
            },
            other => return Err(bad(format!("unknown backend '{other}'"))),
        })
    }
}

/// Round-executor parallelism: how many worker threads the matrix engine
/// partitions its per-node phases across (see `util::pool`). The parallel
/// path is bit-identical to the sequential one (node-partitioned work,
/// sequential reductions), so this is purely a throughput knob.
///
/// JSON forms: `"auto"`, `"off"`, or a positive integer worker count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// One worker per available hardware thread (clamped to node count).
    #[default]
    Auto,
    /// Single-threaded execution on the calling thread.
    Off,
    /// Exactly this many workers (clamped to node count).
    Fixed(usize),
}

impl Parallelism {
    /// Resolve to a concrete worker count for `items` parallel work items.
    pub fn workers(&self, items: usize) -> usize {
        let raw = match self {
            Parallelism::Off => 1,
            Parallelism::Fixed(n) => (*n).max(1),
            Parallelism::Auto => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        };
        raw.min(items.max(1))
    }

    /// Parse the CLI / JSON-string form.
    pub fn parse_str(text: &str) -> Result<Self, ConfigError> {
        match text {
            "auto" => Ok(Parallelism::Auto),
            "off" => Ok(Parallelism::Off),
            other => other
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .map(Parallelism::Fixed)
                .ok_or_else(|| {
                    bad(format!(
                        "parallelism must be 'auto', 'off' or a positive \
                         integer, got '{other}'"
                    ))
                }),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Parallelism::Auto => Json::str("auto"),
            Parallelism::Off => Json::str("off"),
            Parallelism::Fixed(n) => Json::num(*n as f64),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        if let Some(s) = j.as_str() {
            return Self::parse_str(s);
        }
        if let Some(n) = j.as_usize() {
            if n >= 1 {
                return Ok(Parallelism::Fixed(n));
            }
        }
        Err(bad("parallelism must be 'auto', 'off' or a positive integer"))
    }
}

/// What the gossip engines actually transmit per broadcast: the packed
/// [`crate::quant::wire`] bitstream (neighbors reconstruct exclusively
/// from the encoded bytes, and byte accounting is the measured encoded
/// length) or the legacy matrix form (dequantized deltas applied
/// directly, with byte accounting from the same exact size formula).
/// The two paths produce bit-identical models for every quantizer —
/// enforced by `rust/tests/simnet_determinism.rs` — so this is purely a
/// transport/verification knob.
///
/// JSON / CLI forms: `"bitstream"` (default) or `"matrix"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WireEncoding {
    /// legacy in-memory exchange of dequantized deltas
    Matrix,
    /// encode/decode the versioned wire frame per broadcast
    #[default]
    Bitstream,
}

impl WireEncoding {
    pub fn name(&self) -> &'static str {
        match self {
            WireEncoding::Matrix => "matrix",
            WireEncoding::Bitstream => "bitstream",
        }
    }

    pub fn parse_str(text: &str) -> Result<Self, ConfigError> {
        match text {
            "matrix" => Ok(WireEncoding::Matrix),
            "bitstream" => Ok(WireEncoding::Bitstream),
            other => Err(bad(format!(
                "encoding must be 'matrix' or 'bitstream', got '{other}'"
            ))),
        }
    }
}

/// Which gossip engine executes a simulated run: the synchronous
/// round-barrier matrix engine ([`crate::dfl::DflEngine`]) or the
/// asynchronous event-driven engine
/// ([`crate::agossip::AsyncGossipEngine`], nodes proceed on per-node
/// quorum wakeups — no global barrier).
///
/// JSON / CLI forms: `"sync"` (default) or `"async"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EngineMode {
    #[default]
    Sync,
    Async,
}

impl EngineMode {
    pub fn name(&self) -> &'static str {
        match self {
            EngineMode::Sync => "sync",
            EngineMode::Async => "async",
        }
    }

    pub fn parse_str(text: &str) -> Result<Self, ConfigError> {
        match text {
            "sync" => Ok(EngineMode::Sync),
            "async" => Ok(EngineMode::Async),
            other => Err(bad(format!(
                "mode must be 'sync' or 'async', got '{other}'"
            ))),
        }
    }
}

/// How the gossip engines aggregate neighbor estimates in the mixing
/// step. `Metropolis` is the paper's doubly-stochastic confusion-matrix
/// row; the robust variants defend the same row against Byzantine
/// neighbors coordinate-wise (see [`crate::topology::robust`]).
/// `Trimmed { f: 0 }` dispatches to the plain Metropolis path, so the
/// two are bit-identical at f = 0.
///
/// JSON / CLI forms: `"metropolis"` (default), `"trimmed(f)"` (also
/// accepted as `{"kind": "trimmed", "f": n}`), `"median"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MixingKind {
    /// plain Metropolis–Hastings weighted averaging (the paper's C)
    #[default]
    Metropolis,
    /// drop the `f` largest and `f` smallest neighbor values per
    /// coordinate, rescale the surviving neighbor weights
    Trimmed { f: usize },
    /// coordinate-wise median over self + live neighbor estimates
    Median,
}

impl MixingKind {
    /// `true` when this kind runs the plain Metropolis code path
    /// (including the `trimmed(0)` degenerate form — the bit-identity
    /// guarantee at f = 0).
    pub fn is_plain(&self) -> bool {
        matches!(
            self,
            MixingKind::Metropolis | MixingKind::Trimmed { f: 0 }
        )
    }

    /// Canonical display / sweep-axis name (`trimmed(f)` keeps f).
    pub fn label(&self) -> String {
        match self {
            MixingKind::Metropolis => "metropolis".into(),
            MixingKind::Trimmed { f } => format!("trimmed({f})"),
            MixingKind::Median => "median".into(),
        }
    }

    pub fn parse_str(text: &str) -> Result<Self, ConfigError> {
        match text {
            "metropolis" => return Ok(MixingKind::Metropolis),
            "median" => return Ok(MixingKind::Median),
            _ => {}
        }
        if let Some(inner) = text
            .strip_prefix("trimmed(")
            .and_then(|r| r.strip_suffix(')'))
        {
            if let Ok(f) = inner.trim().parse::<usize>() {
                return Ok(MixingKind::Trimmed { f });
            }
        }
        Err(bad(format!(
            "mixing must be 'metropolis', 'trimmed(f)' or 'median', \
             got '{text}'"
        )))
    }

    pub fn to_json(&self) -> Json {
        match self {
            MixingKind::Trimmed { f } => Json::obj(vec![
                ("kind", Json::str("trimmed")),
                ("f", Json::num(*f as f64)),
            ]),
            MixingKind::Metropolis => Json::str("metropolis"),
            MixingKind::Median => Json::str("median"),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        if let Some(s) = j.as_str() {
            return Self::parse_str(s);
        }
        match j.get_str("kind") {
            Some("trimmed") => Ok(MixingKind::Trimmed {
                f: j.get_usize("f").unwrap_or(1),
            }),
            Some(other) => Self::parse_str(other),
            None => Err(bad("mixing.kind missing")),
        }
    }
}

/// Byzantine sender behaviors for the `attack:` section. The corruption
/// is injected into the outgoing delta at the wire-encode boundary
/// ([`crate::dfl::core::NodeCore`]), *before* quantization, so every
/// engine, encoding, and transport faces the identical adversary and
/// the attacker stays wire-consistent (its own estimate x̂ tracks the
/// corrupted stream it broadcasts).
#[derive(Clone, Debug, PartialEq)]
pub enum AttackKind {
    /// broadcast −δ instead of δ (estimate error doubles per message)
    SignFlip,
    /// broadcast `factor`·δ (scaled-gradient attack)
    Scale { factor: f64 },
    /// broadcast a seeded random vector at the honest delta's scale
    Random,
}

impl AttackKind {
    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::SignFlip => "sign_flip",
            AttackKind::Scale { .. } => "scale",
            AttackKind::Random => "random",
        }
    }
}

/// `attack:` config section — which Byzantine behavior the first `f`
/// node ids run. Deterministic by construction: roles are a pure
/// function of the config, and the random-message attacker draws from
/// its own dedicated rng split, so attacked runs replay byte-exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct AttackConfig {
    pub kind: AttackKind,
    /// number of Byzantine nodes (ids `0..f`)
    pub f: usize,
}

impl AttackConfig {
    /// The Byzantine behavior node `i` runs, if any.
    pub fn role(&self, node: usize) -> Option<&AttackKind> {
        (node < self.f).then_some(&self.kind)
    }

    pub fn validate(&self, nodes: usize) -> Result<(), ConfigError> {
        if self.f > nodes {
            return Err(bad(format!(
                "attack.f = {} exceeds the {nodes}-node fleet",
                self.f
            )));
        }
        if let AttackKind::Scale { factor } = self.kind {
            if !factor.is_finite() {
                return Err(bad("attack.factor must be finite"));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(self.kind.name())),
            ("f", Json::num(self.f as f64)),
        ];
        if let AttackKind::Scale { factor } = self.kind {
            pairs.push(("factor", Json::num(factor)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let kind = match j
            .get_str("kind")
            .ok_or_else(|| bad("attack.kind missing"))?
        {
            "sign_flip" => AttackKind::SignFlip,
            "scale" => AttackKind::Scale {
                factor: j.get_f64("factor").unwrap_or(-4.0),
            },
            "random" => AttackKind::Random,
            other => {
                return Err(bad(format!(
                    "attack.kind must be 'sign_flip', 'scale' or \
                     'random', got '{other}'"
                )))
            }
        };
        Ok(AttackConfig { kind, f: j.get_usize("f").unwrap_or(1) })
    }
}

/// Learning-rate schedule. The paper evaluates fixed η and a variable η_k
/// decaying 20% every 10 iterations (Fig. 8).
#[derive(Clone, Debug, PartialEq)]
pub struct LrSchedule {
    pub base: f64,
    /// multiplicative decay applied every `decay_every` rounds (1.0 = fixed)
    pub decay: f64,
    pub decay_every: usize,
}

impl LrSchedule {
    pub fn fixed(base: f64) -> Self {
        LrSchedule { base, decay: 1.0, decay_every: 1 }
    }

    /// Paper Fig. 8 variable rate: −20% per 10 iterations.
    pub fn paper_variable(base: f64) -> Self {
        LrSchedule { base, decay: 0.8, decay_every: 10 }
    }

    /// η_k for round k (0-based).
    pub fn at(&self, round: usize) -> f64 {
        let steps = if self.decay_every == 0 {
            0
        } else {
            round / self.decay_every
        };
        self.base * self.decay.powi(steps as i32)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base", Json::num(self.base)),
            ("decay", Json::num(self.decay)),
            ("decay_every", Json::num(self.decay_every as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        Ok(LrSchedule {
            base: j.get_f64("base").ok_or_else(|| bad("lr.base missing"))?,
            decay: j.get_f64("decay").unwrap_or(1.0),
            decay_every: j.get_usize("decay_every").unwrap_or(1),
        })
    }
}

/// Full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// number of nodes N
    pub nodes: usize,
    /// local updates per round (paper τ)
    pub tau: usize,
    /// total communication rounds K
    pub rounds: usize,
    pub batch_size: usize,
    pub lr: LrSchedule,
    pub topology: TopologyKind,
    pub quantizer: QuantizerKind,
    pub dataset: DatasetKind,
    pub backend: BackendKind,
    /// fraction of samples assigned by-label (paper: 0.5 non-IID split)
    pub noniid_fraction: f64,
    /// link rate used to convert bits to "time progression" (paper: 100 Mbps)
    pub link_bps: f64,
    /// evaluate global loss/accuracy every this many rounds
    pub eval_every: usize,
    /// worker threads for the matrix engine's per-node phases
    pub parallelism: Parallelism,
    /// `network:` section — the simnet fabric model (heterogeneous
    /// links, stragglers, churn). `None` = ideal instantaneous network;
    /// `Some` enables `DflEngine::run_simulated` / `lmdfl train
    /// --simulate` virtual-time runs. See [`crate::simnet`].
    pub network: Option<crate::simnet::NetworkConfig>,
    /// which engine executes simulated runs (`sync` default / `async`)
    pub mode: EngineMode,
    /// what broadcasts physically carry (`bitstream` default / `matrix`)
    pub encoding: WireEncoding,
    /// `async:` section — quorum policy, staleness weighting, and timer
    /// knobs of the asynchronous engine. `None` = defaults. Only
    /// consulted when `mode == async`. See [`crate::agossip`].
    pub agossip: Option<crate::agossip::AsyncConfig>,
    /// `transport:` section — which [`crate::net::Delivery`] backend
    /// the threaded runtime uses (`channel` default / `tcp`) and the
    /// TCP endpoint parameters. `None` = in-process channels.
    pub transport: Option<crate::net::TransportConfig>,
    /// `observe:` section — tracing/telemetry sinks (JSONL trace and
    /// Chrome `trace_event` paths). `None` = tracing disabled; see
    /// [`crate::obs`]. Never affects simulated results: traced runs
    /// are byte-identical to untraced ones.
    pub observe: Option<crate::obs::ObserveConfig>,
    /// `attack:` section — Byzantine sender behaviors for the first
    /// `f` node ids. `None` = every node honest.
    pub attack: Option<AttackConfig>,
    /// mixing-step aggregation (`metropolis` default, or the robust
    /// `trimmed(f)` / `median` variants)
    pub mixing: MixingKind,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 0,
            nodes: 10,
            tau: 4,
            rounds: 100,
            batch_size: 32,
            lr: LrSchedule::fixed(0.05),
            topology: TopologyKind::Ring,
            quantizer: QuantizerKind::LloydMax { s: 16, iters: 12 },
            dataset: DatasetKind::SynthMnist { train: 2000, test: 500 },
            backend: BackendKind::RustMlp { hidden: vec![64] },
            noniid_fraction: 0.5,
            link_bps: 100e6,
            eval_every: 1,
            parallelism: Parallelism::Auto,
            network: None,
            mode: EngineMode::Sync,
            encoding: WireEncoding::Bitstream,
            agossip: None,
            transport: None,
            observe: None,
            attack: None,
            mixing: MixingKind::Metropolis,
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.nodes == 0 {
            return Err(bad("nodes must be > 0"));
        }
        if self.tau == 0 {
            return Err(bad("tau must be > 0"));
        }
        if self.rounds == 0 {
            return Err(bad("rounds must be > 0"));
        }
        if self.batch_size == 0 {
            return Err(bad("batch_size must be > 0"));
        }
        if !(0.0..=1.0).contains(&self.noniid_fraction) {
            return Err(bad("noniid_fraction must be in [0,1]"));
        }
        if self.lr.base <= 0.0 {
            return Err(bad("lr.base must be > 0"));
        }
        if let TopologyKind::Random { p } = self.topology {
            if !(0.0..=1.0).contains(&p) {
                return Err(bad("topology.p must be in [0,1]"));
            }
        }
        if let TopologyKind::RandomRegular { k } = self.topology {
            if k < 2 {
                return Err(bad("topology.k must be >= 2"));
            }
            if k >= self.nodes {
                return Err(bad("topology.k must be < nodes"));
            }
            if (self.nodes * k) % 2 != 0 {
                return Err(bad("topology requires nodes*k even"));
            }
        }
        match &self.quantizer {
            QuantizerKind::Qsgd { s }
            | QuantizerKind::Natural { s }
            | QuantizerKind::Alq { s }
            | QuantizerKind::LloydMax { s, .. } => {
                if *s < 2 {
                    return Err(bad("quantizer.s must be >= 2"));
                }
            }
            QuantizerKind::DoublyAdaptive { s1, s_max, .. } => {
                if *s1 < 2 || s_max < s1 {
                    return Err(bad("need 2 <= s1 <= s_max"));
                }
            }
            QuantizerKind::Full | QuantizerKind::TernGrad => {}
            QuantizerKind::TopK { keep } => {
                if !(*keep > 0.0 && *keep <= 1.0) {
                    return Err(bad("quantizer.keep must be in (0,1]"));
                }
            }
        }
        if let Some(a) = &self.attack {
            a.validate(self.nodes)?;
        }
        if let Some(net) = &self.network {
            net.validate()?;
        }
        if let Some(a) = &self.agossip {
            a.validate()?;
        }
        if let Some(t) = &self.transport {
            t.validate(self.nodes)?;
        }
        if let Some(o) = &self.observe {
            o.validate()?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("seed", Json::num(self.seed as f64)),
            ("nodes", Json::num(self.nodes as f64)),
            ("tau", Json::num(self.tau as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("lr", self.lr.to_json()),
            ("topology", self.topology.to_json()),
            ("quantizer", self.quantizer.to_json()),
            ("dataset", self.dataset.to_json()),
            ("backend", self.backend.to_json()),
            ("noniid_fraction", Json::num(self.noniid_fraction)),
            ("link_bps", Json::num(self.link_bps)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("parallelism", self.parallelism.to_json()),
        ];
        if let Some(net) = &self.network {
            pairs.push(("network", net.to_json()));
        }
        if self.mode != EngineMode::Sync {
            pairs.push(("mode", Json::str(self.mode.name())));
        }
        if self.encoding != WireEncoding::default() {
            pairs.push(("encoding", Json::str(self.encoding.name())));
        }
        if let Some(a) = &self.agossip {
            pairs.push(("async", a.to_json()));
        }
        if let Some(t) = &self.transport {
            pairs.push(("transport", t.to_json()));
        }
        if let Some(o) = &self.observe {
            pairs.push(("observe", o.to_json()));
        }
        if let Some(a) = &self.attack {
            pairs.push(("attack", a.to_json()));
        }
        if self.mixing != MixingKind::default() {
            pairs.push(("mixing", self.mixing.to_json()));
        }
        Json::obj(pairs)
    }

    /// The canonical identity of this experiment: [`Self::to_json`]
    /// with the `observe:` section stripped. Tracing never affects
    /// results (traced runs are byte-identical to untraced ones), so
    /// two configs differing only in trace sink paths describe the
    /// same experiment — sweep config hashing and resume keying build
    /// on this form. Object keys are BTreeMap-sorted, so the compact
    /// serialization is deterministic.
    pub fn identity_json(&self) -> Json {
        let mut stripped = self.clone();
        stripped.observe = None;
        stripped.to_json()
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let d = ExperimentConfig::default();
        let cfg = ExperimentConfig {
            name: j.get_str("name").unwrap_or("unnamed").to_string(),
            seed: j.get_f64("seed").unwrap_or(0.0) as u64,
            nodes: j.get_usize("nodes").unwrap_or(d.nodes),
            tau: j.get_usize("tau").unwrap_or(d.tau),
            rounds: j.get_usize("rounds").unwrap_or(d.rounds),
            batch_size: j.get_usize("batch_size").unwrap_or(d.batch_size),
            lr: match j.get("lr") {
                Some(lj) => LrSchedule::from_json(lj)?,
                None => d.lr.clone(),
            },
            topology: match j.get("topology") {
                Some(tj) => TopologyKind::from_json(tj)?,
                None => d.topology.clone(),
            },
            quantizer: match j.get("quantizer") {
                Some(qj) => QuantizerKind::from_json(qj)?,
                None => d.quantizer.clone(),
            },
            dataset: match j.get("dataset") {
                Some(dj) => DatasetKind::from_json(dj)?,
                None => d.dataset.clone(),
            },
            backend: match j.get("backend") {
                Some(bj) => BackendKind::from_json(bj)?,
                None => d.backend.clone(),
            },
            noniid_fraction: j
                .get_f64("noniid_fraction")
                .unwrap_or(d.noniid_fraction),
            link_bps: j.get_f64("link_bps").unwrap_or(d.link_bps),
            eval_every: j.get_usize("eval_every").unwrap_or(d.eval_every),
            parallelism: match j.get("parallelism") {
                Some(pj) => Parallelism::from_json(pj)?,
                None => d.parallelism,
            },
            network: match j.get("network") {
                Some(nj) => {
                    Some(crate::simnet::NetworkConfig::from_json(nj)?)
                }
                None => None,
            },
            mode: match j.get_str("mode") {
                Some(m) => EngineMode::parse_str(m)?,
                None => EngineMode::Sync,
            },
            encoding: match j.get_str("encoding") {
                Some(e) => WireEncoding::parse_str(e)?,
                None => WireEncoding::default(),
            },
            agossip: match j.get("async") {
                Some(aj) => {
                    Some(crate::agossip::AsyncConfig::from_json(aj)?)
                }
                None => None,
            },
            transport: match j.get("transport") {
                Some(tj) => {
                    Some(crate::net::TransportConfig::from_json(tj)?)
                }
                None => None,
            },
            observe: match j.get("observe") {
                Some(oj) => {
                    Some(crate::obs::ObserveConfig::from_json(oj)?)
                }
                None => None,
            },
            attack: match j.get("attack") {
                Some(aj) => Some(AttackConfig::from_json(aj)?),
                None => None,
            },
            mixing: match j.get("mixing") {
                Some(mj) => MixingKind::from_json(mj)?,
                None => MixingKind::default(),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let j = Json::parse(text)
            .map_err(|e| bad(format!("invalid json: {e}")))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "rt".into();
        cfg.quantizer = QuantizerKind::DoublyAdaptive {
            s1: 4,
            iters: 9,
            s_max: 1024,
        };
        cfg.topology = TopologyKind::Random { p: 0.3 };
        cfg.lr = LrSchedule::paper_variable(0.002);
        cfg.backend = BackendKind::Hlo { artifact: "mlp_mnist".into() };
        cfg.parallelism = Parallelism::Fixed(3);
        cfg.transport = Some(crate::net::TransportConfig::tcp_default());
        cfg.observe = Some(crate::obs::ObserveConfig {
            trace_path: Some("/tmp/run.jsonl".into()),
            chrome_path: None,
        });
        let text = cfg.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn identity_json_ignores_observe_only() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "ident".into();
        let bare = cfg.identity_json().to_string();
        cfg.observe = Some(crate::obs::ObserveConfig {
            trace_path: Some("/tmp/a/trace.jsonl".into()),
            chrome_path: None,
        });
        assert_eq!(cfg.identity_json().to_string(), bare);
        assert!(!bare.contains("observe"));
        // anything else still changes the identity
        cfg.seed = 99;
        assert_ne!(cfg.identity_json().to_string(), bare);
    }

    #[test]
    fn random_regular_roundtrip_and_validation() {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 16;
        cfg.topology = TopologyKind::RandomRegular { k: 4 };
        cfg.validate().unwrap();
        let text = cfg.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back.topology, cfg.topology);
        // degree floor
        cfg.topology = TopologyKind::RandomRegular { k: 1 };
        assert!(cfg.validate().is_err());
        // degree must leave at least one non-neighbor
        cfg.topology = TopologyKind::RandomRegular { k: 16 };
        assert!(cfg.validate().is_err());
        // pairing model needs an even number of stubs
        cfg.nodes = 5;
        cfg.topology = TopologyKind::RandomRegular { k: 3 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn observe_section_forms() {
        // absent -> None (tracing disabled)
        let cfg = ExperimentConfig::parse(r#"{"name": "o"}"#).unwrap();
        assert!(cfg.observe.is_none());
        // a sink enables it
        let cfg = ExperimentConfig::parse(
            r#"{"name": "o",
                "observe": {"trace_path": "/tmp/o.jsonl"}}"#,
        )
        .unwrap();
        let o = cfg.observe.clone().unwrap();
        assert_eq!(o.trace_path.as_deref(), Some("/tmp/o.jsonl"));
        assert!(o.chrome_path.is_none());
        let text = cfg.to_json().to_pretty();
        assert_eq!(ExperimentConfig::parse(&text).unwrap(), cfg);
        // an empty observe section is rejected
        assert!(ExperimentConfig::parse(
            r#"{"name": "o", "observe": {}}"#
        )
        .is_err());
    }

    #[test]
    fn parallelism_forms_parse() {
        assert_eq!(
            Parallelism::parse_str("auto").unwrap(),
            Parallelism::Auto
        );
        assert_eq!(Parallelism::parse_str("off").unwrap(), Parallelism::Off);
        assert_eq!(
            Parallelism::parse_str("4").unwrap(),
            Parallelism::Fixed(4)
        );
        assert!(Parallelism::parse_str("0").is_err());
        assert!(Parallelism::parse_str("many").is_err());

        let cfg = ExperimentConfig::parse(
            r#"{"name": "p", "parallelism": "off"}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Off);
        let cfg = ExperimentConfig::parse(
            r#"{"name": "p", "parallelism": 2}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Fixed(2));
        assert!(ExperimentConfig::parse(
            r#"{"name": "p", "parallelism": 0}"#).is_err());
        // absent -> default (auto)
        let cfg = ExperimentConfig::parse(r#"{"name": "p"}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Auto);
    }

    #[test]
    fn parallelism_worker_resolution() {
        assert_eq!(Parallelism::Off.workers(16), 1);
        assert_eq!(Parallelism::Fixed(4).workers(16), 4);
        // clamped to the number of work items
        assert_eq!(Parallelism::Fixed(32).workers(5), 5);
        assert!(Parallelism::Auto.workers(16) >= 1);
        assert!(Parallelism::Auto.workers(2) <= 2);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg = ExperimentConfig::parse(r#"{"name": "x", "nodes": 4}"#)
            .unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.tau, ExperimentConfig::default().tau);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(ExperimentConfig::parse(r#"{"nodes": 0}"#).is_err());
        assert!(ExperimentConfig::parse(
            r#"{"quantizer": {"kind": "qsgd", "s": 1}}"#).is_err());
        assert!(ExperimentConfig::parse(
            r#"{"quantizer": {"kind": "bogus"}}"#).is_err());
        assert!(ExperimentConfig::parse("not json").is_err());
    }

    #[test]
    fn network_section_roundtrip_and_defaults() {
        // absent -> None (ideal network)
        let cfg = ExperimentConfig::parse(r#"{"name": "n"}"#).unwrap();
        assert!(cfg.network.is_none());
        // partial section fills defaults
        let cfg = ExperimentConfig::parse(
            r#"{"name": "n", "network": {"bandwidth_bps": 1e6,
                "compute": {"straggler_prob": 0.25}}}"#,
        )
        .unwrap();
        let net = cfg.network.clone().unwrap();
        assert_eq!(net.link.bandwidth_bps, 1e6);
        assert_eq!(net.compute.straggler_prob, 0.25);
        assert_eq!(net.link.latency_s, 0.0);
        // full roundtrip through to_json
        let text = cfg.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back, cfg);
        // invalid network fields are rejected at the config level
        assert!(ExperimentConfig::parse(
            r#"{"name": "n", "network": {"drop_prob": 7.0}}"#
        )
        .is_err());
    }

    #[test]
    fn mode_and_async_section_roundtrip() {
        // absent -> sync, no async section
        let cfg = ExperimentConfig::parse(r#"{"name": "m"}"#).unwrap();
        assert_eq!(cfg.mode, EngineMode::Sync);
        assert!(cfg.agossip.is_none());
        // async mode with a quorum policy
        let cfg = ExperimentConfig::parse(
            r#"{"name": "m", "mode": "async",
                "async": {"wait_for": "quorum", "quorum": 3,
                          "staleness_lambda": 0.7,
                          "quorum_timeout_s": 2.0}}"#,
        )
        .unwrap();
        assert_eq!(cfg.mode, EngineMode::Async);
        let a = cfg.agossip.clone().unwrap();
        assert_eq!(
            a.wait_for,
            crate::agossip::WaitPolicy::Quorum { k: 3 }
        );
        assert_eq!(a.staleness_lambda, 0.7);
        // full roundtrip through to_json
        let text = cfg.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back, cfg);
        // invalid forms rejected
        assert!(ExperimentConfig::parse(
            r#"{"name": "m", "mode": "banana"}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"name": "m", "async": {"staleness_lambda": 0.0}}"#
        )
        .is_err());
    }

    #[test]
    fn encoding_forms_parse_and_roundtrip() {
        // absent -> bitstream (the default transport)
        let cfg = ExperimentConfig::parse(r#"{"name": "e"}"#).unwrap();
        assert_eq!(cfg.encoding, WireEncoding::Bitstream);
        // explicit forms
        let cfg = ExperimentConfig::parse(
            r#"{"name": "e", "encoding": "matrix"}"#,
        )
        .unwrap();
        assert_eq!(cfg.encoding, WireEncoding::Matrix);
        let cfg = ExperimentConfig::parse(
            r#"{"name": "e", "encoding": "bitstream"}"#,
        )
        .unwrap();
        assert_eq!(cfg.encoding, WireEncoding::Bitstream);
        // non-default form survives a to_json roundtrip
        let mut cfg = ExperimentConfig::default();
        cfg.encoding = WireEncoding::Matrix;
        let back =
            ExperimentConfig::parse(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(back, cfg);
        // unknown form rejected
        assert!(ExperimentConfig::parse(
            r#"{"name": "e", "encoding": "telepathy"}"#
        )
        .is_err());
    }

    #[test]
    fn attack_section_forms() {
        // absent -> None (honest fleet)
        let cfg = ExperimentConfig::parse(r#"{"name": "a"}"#).unwrap();
        assert!(cfg.attack.is_none());
        // sign-flip roles hit exactly the first f node ids
        let cfg = ExperimentConfig::parse(
            r#"{"name": "a", "nodes": 8,
                "attack": {"kind": "sign_flip", "f": 2}}"#,
        )
        .unwrap();
        let a = cfg.attack.clone().unwrap();
        assert_eq!(a.role(0), Some(&AttackKind::SignFlip));
        assert_eq!(a.role(1), Some(&AttackKind::SignFlip));
        assert_eq!(a.role(2), None);
        let text = cfg.to_json().to_pretty();
        assert_eq!(ExperimentConfig::parse(&text).unwrap(), cfg);
        // scale keeps its factor through the roundtrip
        let cfg = ExperimentConfig::parse(
            r#"{"name": "a", "nodes": 8,
                "attack": {"kind": "scale", "f": 1, "factor": -4.0}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.attack.as_ref().unwrap().kind,
            AttackKind::Scale { factor: -4.0 }
        );
        let text = cfg.to_json().to_pretty();
        assert_eq!(ExperimentConfig::parse(&text).unwrap(), cfg);
        // f > nodes and unknown kinds are rejected
        assert!(ExperimentConfig::parse(
            r#"{"name": "a", "nodes": 4,
                "attack": {"kind": "random", "f": 5}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"name": "a", "attack": {"kind": "eclipse", "f": 1}}"#
        )
        .is_err());
    }

    #[test]
    fn mixing_forms_parse_and_roundtrip() {
        // absent -> metropolis (the paper's C)
        let cfg = ExperimentConfig::parse(r#"{"name": "x"}"#).unwrap();
        assert_eq!(cfg.mixing, MixingKind::Metropolis);
        // string and object forms
        assert_eq!(
            MixingKind::parse_str("trimmed(2)").unwrap(),
            MixingKind::Trimmed { f: 2 }
        );
        assert_eq!(
            MixingKind::parse_str("median").unwrap(),
            MixingKind::Median
        );
        assert!(MixingKind::parse_str("trimmed(x)").is_err());
        assert!(MixingKind::parse_str("mean").is_err());
        let cfg = ExperimentConfig::parse(
            r#"{"name": "x", "mixing": "trimmed(2)"}"#,
        )
        .unwrap();
        assert_eq!(cfg.mixing, MixingKind::Trimmed { f: 2 });
        let text = cfg.to_json().to_pretty();
        assert_eq!(ExperimentConfig::parse(&text).unwrap(), cfg);
        let cfg = ExperimentConfig::parse(
            r#"{"name": "x", "mixing": {"kind": "trimmed", "f": 3}}"#,
        )
        .unwrap();
        assert_eq!(cfg.mixing, MixingKind::Trimmed { f: 3 });
        // trimmed(0) is the plain path; labels are stable
        assert!(MixingKind::Trimmed { f: 0 }.is_plain());
        assert!(!MixingKind::Trimmed { f: 1 }.is_plain());
        assert_eq!(MixingKind::Trimmed { f: 2 }.label(), "trimmed(2)");
    }

    #[test]
    fn sparsifier_kinds_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.quantizer = QuantizerKind::TopK { keep: 0.25 };
        cfg.validate().unwrap();
        let back =
            ExperimentConfig::parse(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(back.quantizer, cfg.quantizer);
        cfg.quantizer = QuantizerKind::TernGrad;
        let back =
            ExperimentConfig::parse(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(back.quantizer, QuantizerKind::TernGrad);
        // keep outside (0,1] is rejected
        cfg.quantizer = QuantizerKind::TopK { keep: 0.0 };
        assert!(cfg.validate().is_err());
        cfg.quantizer = QuantizerKind::TopK { keep: 1.5 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn lr_schedule_paper_variable() {
        let lr = LrSchedule::paper_variable(1.0);
        assert!((lr.at(0) - 1.0).abs() < 1e-12);
        assert!((lr.at(9) - 1.0).abs() < 1e-12);
        assert!((lr.at(10) - 0.8).abs() < 1e-12);
        assert!((lr.at(25) - 0.64).abs() < 1e-12);
    }
}
