//! Configuration substrate: hand-written JSON + typed experiment schema.

pub mod json;
pub mod schema;

pub use json::Json;
pub use schema::{
    AttackConfig, AttackKind, BackendKind, ConfigError, DatasetKind,
    EngineMode, ExperimentConfig, LrSchedule, MixingKind, Parallelism,
    QuantizerKind, TopologyKind, WireEncoding,
};

use std::path::Path;

/// Load an [`ExperimentConfig`] from a JSON file.
pub fn load_config(path: &Path) -> anyhow::Result<ExperimentConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(ExperimentConfig::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_config_from_file() {
        let dir = std::env::temp_dir();
        let path = dir.join("lmdfl_test_config.json");
        let cfg = ExperimentConfig::default();
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let back = load_config(&path).unwrap();
        assert_eq!(back, cfg);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_config_missing_file_errors() {
        assert!(load_config(Path::new("/nonexistent/x.json")).is_err());
    }
}
