//! The crate-wide error type of the public API surface.
//!
//! Every fallible operation the prelude exposes either returns
//! [`LmdflError`] directly (the transport layer) or an `anyhow::Result`
//! whose root cause is one of its variants (the runners, which chain
//! many subsystems). The variants are *typed*: callers can match on
//! truncation vs version-mismatch vs OS io instead of grepping message
//! strings, and [`std::error::Error::source`] chains to the concrete
//! inner error for diagnostics.
//!
//! The per-module error types ([`ConfigError`], [`CodecError`]) stay —
//! they carry the structured detail — but at API boundaries they travel
//! inside an `LmdflError`, which the vendored `anyhow`'s blanket
//! `From<E: std::error::Error>` lifts through `?` without ceremony.

use std::fmt;

use crate::config::ConfigError;
use crate::quant::codec::CodecError;

/// Unified error of the `lmdfl` public API.
#[derive(Debug)]
pub enum LmdflError {
    /// Configuration parsing or validation failed.
    Config(ConfigError),
    /// A wire frame failed to decode. Match on the inner
    /// [`CodecError`] to distinguish [`CodecError::Truncated`] from
    /// [`CodecError::Version`] from structural corruption.
    Codec(CodecError),
    /// An OS-level I/O operation failed (sockets, files).
    Io(std::io::Error),
    /// A transport-level failure that is not a raw OS error: a peer
    /// unreachable after the retry budget, a closed endpoint, or a
    /// violated delivery contract.
    Transport {
        /// The peer involved, when the failure is per-link.
        peer: Option<usize>,
        detail: String,
    },
}

impl LmdflError {
    /// Build a [`LmdflError::Transport`] error.
    pub fn transport(
        peer: impl Into<Option<usize>>,
        detail: impl Into<String>,
    ) -> LmdflError {
        LmdflError::Transport { peer: peer.into(), detail: detail.into() }
    }
}

impl fmt::Display for LmdflError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LmdflError::Config(e) => write!(f, "{e}"),
            LmdflError::Codec(e) => write!(f, "{e}"),
            LmdflError::Io(e) => write!(f, "io error: {e}"),
            LmdflError::Transport { peer: Some(p), detail } => {
                write!(f, "transport error (peer {p}): {detail}")
            }
            LmdflError::Transport { peer: None, detail } => {
                write!(f, "transport error: {detail}")
            }
        }
    }
}

impl std::error::Error for LmdflError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LmdflError::Config(e) => Some(e),
            LmdflError::Codec(e) => Some(e),
            LmdflError::Io(e) => Some(e),
            LmdflError::Transport { .. } => None,
        }
    }
}

impl From<ConfigError> for LmdflError {
    fn from(e: ConfigError) -> LmdflError {
        LmdflError::Config(e)
    }
}

impl From<CodecError> for LmdflError {
    fn from(e: CodecError) -> LmdflError {
        LmdflError::Codec(e)
    }
}

impl From<std::io::Error> for LmdflError {
    fn from(e: std::io::Error) -> LmdflError {
        LmdflError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error as _;

    #[test]
    fn variants_are_matchable_and_chained() {
        let io: LmdflError =
            std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(io, LmdflError::Io(_)));
        assert!(io.source().is_some());

        let codec: LmdflError =
            CodecError::Version { got: 9, want: 1 }.into();
        match &codec {
            LmdflError::Codec(CodecError::Version { got, want }) => {
                assert_eq!((*got, *want), (9, 1));
            }
            other => panic!("wrong variant: {other}"),
        }

        let cfg: LmdflError = ConfigError("nodes must be > 0".into()).into();
        assert!(cfg.to_string().contains("config error"));

        let t = LmdflError::transport(3, "peer unreachable");
        assert!(t.to_string().contains("peer 3"));
        assert!(t.source().is_none());
    }

    #[test]
    fn lifts_into_anyhow_via_question_mark() {
        fn inner() -> anyhow::Result<()> {
            Err(LmdflError::transport(None, "closed endpoint"))?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("closed endpoint"));
    }
}
