//! Synthetic datasets + the paper's non-IID partitioner.
//!
//! §Substitutions (DESIGN.md): offline, MNIST/CIFAR-10 are replaced by
//! procedurally generated datasets with the same shapes, class counts and
//! split semantics — the paper's claims are about communication, which
//! these exercise identically.

pub mod blobs;
pub mod partition;
pub mod synth_cifar;
pub mod synth_mnist;

use crate::config::DatasetKind;
use crate::util::rng::Rng;

/// An in-memory classification dataset (row-major flat features).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// train features, `train_n x feat_dim`
    pub train_x: Vec<f32>,
    pub train_y: Vec<u32>,
    /// test features, `test_n x feat_dim`
    pub test_x: Vec<f32>,
    pub test_y: Vec<u32>,
    pub feat_dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn train_n(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_n(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    pub fn test_row(&self, i: usize) -> &[f32] {
        &self.test_x[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    /// Gather a batch (features, labels) from train-set indices.
    pub fn gather_batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<u32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.gather_batch_into(idx, &mut x, &mut y);
        (x, y)
    }

    /// Gather a batch into caller-owned buffers (hot path: the round
    /// executor reuses per-node scratch so τ·rounds batch gathers cost no
    /// allocations after warm-up).
    pub fn gather_batch_into(
        &self,
        idx: &[usize],
        x: &mut Vec<f32>,
        y: &mut Vec<u32>,
    ) {
        x.clear();
        x.reserve(idx.len() * self.feat_dim);
        y.clear();
        y.reserve(idx.len());
        for &i in idx {
            x.extend_from_slice(self.train_row(i));
            y.push(self.train_y[i]);
        }
    }

    /// Build from config.
    pub fn build(kind: &DatasetKind, seed: u64) -> Dataset {
        match kind {
            DatasetKind::SynthMnist { train, test } => {
                synth_mnist::generate(*train, *test, seed)
            }
            DatasetKind::SynthCifar { train, test } => {
                synth_cifar::generate(*train, *test, seed)
            }
            DatasetKind::Blobs { train, test, dim, classes } => {
                blobs::generate(*train, *test, *dim, *classes, seed)
            }
        }
    }
}

/// Per-node mini-batch sampler over a node's local index set.
#[derive(Clone, Debug)]
pub struct BatchSampler {
    indices: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(indices: Vec<usize>, rng: Rng) -> Self {
        assert!(!indices.is_empty(), "node has no local data");
        let mut s = BatchSampler { indices, cursor: 0, rng };
        s.rng.shuffle(&mut s.indices);
        s
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next mini-batch of up to `batch` indices; reshuffles each epoch.
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.next_batch_into(batch, &mut out);
        out
    }

    /// As [`next_batch`](BatchSampler::next_batch), into a caller-owned
    /// buffer (hot path; same index sequence).
    pub fn next_batch_into(&mut self, batch: usize, out: &mut Vec<usize>) {
        let batch = batch.min(self.indices.len());
        out.clear();
        out.reserve(batch);
        for _ in 0..batch {
            if self.cursor >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        blobs::generate(60, 20, 4, 3, 0)
    }

    #[test]
    fn build_from_all_kinds() {
        let kinds = [
            DatasetKind::SynthMnist { train: 50, test: 10 },
            DatasetKind::SynthCifar { train: 50, test: 10 },
            DatasetKind::Blobs { train: 50, test: 10, dim: 8, classes: 4 },
        ];
        for k in &kinds {
            let d = Dataset::build(k, 1);
            assert_eq!(d.train_n(), 50);
            assert_eq!(d.test_n(), 10);
            assert_eq!(d.train_x.len(), 50 * d.feat_dim);
            assert!(d.train_y.iter().all(|&y| (y as usize) < d.classes));
        }
    }

    #[test]
    fn gather_batch_shapes() {
        let d = tiny();
        let (x, y) = d.gather_batch(&[0, 5, 7]);
        assert_eq!(x.len(), 3 * d.feat_dim);
        assert_eq!(y.len(), 3);
        assert_eq!(&x[..d.feat_dim], d.train_row(0));
    }

    #[test]
    fn sampler_covers_epoch() {
        let mut s = BatchSampler::new((0..10).collect(), Rng::new(0));
        let mut seen = vec![0usize; 10];
        for _ in 0..2 {
            let b = s.next_batch(5);
            assert_eq!(b.len(), 5);
            for i in b {
                seen[i] += 1;
            }
        }
        // one full epoch: every index exactly once
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn sampler_batch_larger_than_data() {
        let mut s = BatchSampler::new(vec![1, 2, 3], Rng::new(0));
        let b = s.next_batch(10);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Dataset::build(
            &DatasetKind::SynthMnist { train: 20, test: 5 }, 9);
        let b = Dataset::build(
            &DatasetKind::SynthMnist { train: 20, test: 5 }, 9);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
    }
}
