//! synth-MNIST: procedural 28x28 grayscale digit glyphs.
//!
//! Digits are rendered seven-segment style (segments of the classic LED
//! layout), rasterized with thick anti-aliased strokes, then augmented per
//! sample with random shift, scale, shear and pixel noise. Ten visually
//! distinct, genuinely learnable classes with the exact MNIST shape
//! (1x28x28), replacing the offline-unavailable MNIST per DESIGN.md
//! §Substitutions.

use super::Dataset;
use crate::util::rng::Rng;

pub const IMG: usize = 28;
pub const CLASSES: usize = 10;

/// Seven-segment truth table: segments a,b,c,d,e,f,g for digits 0-9.
///    aaaa
///   f    b
///    gggg
///   e    c
///    dddd
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, true, true, true, false],     // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],    // 2
    [true, true, true, true, false, false, true],    // 3
    [false, true, true, false, false, true, true],   // 4
    [true, false, true, true, false, true, true],    // 5
    [true, false, true, true, true, true, true],     // 6
    [true, true, true, false, false, false, false],  // 7
    [true, true, true, true, true, true, true],      // 8
    [true, true, true, true, false, true, true],     // 9
];

/// Segment endpoints in a unit box [0,1]^2 (x right, y down).
const SEG_LINES: [[f32; 4]; 7] = [
    [0.2, 0.1, 0.8, 0.1], // a (top)
    [0.8, 0.1, 0.8, 0.5], // b (top right)
    [0.8, 0.5, 0.8, 0.9], // c (bottom right)
    [0.2, 0.9, 0.8, 0.9], // d (bottom)
    [0.2, 0.5, 0.2, 0.9], // e (bottom left)
    [0.2, 0.1, 0.2, 0.5], // f (top left)
    [0.2, 0.5, 0.8, 0.5], // g (middle)
];

/// Distance from point to segment, in unit-box coordinates.
fn seg_dist(px: f32, py: f32, l: &[f32; 4]) -> f32 {
    let (x1, y1, x2, y2) = (l[0], l[1], l[2], l[3]);
    let (dx, dy) = (x2 - x1, y2 - y1);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - x1) * dx + (py - y1) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (x1 + t * dx, y1 + t * dy);
    ((px - cx) * (px - cx) + (py - cy) * (py - cy)).sqrt()
}

/// Render one digit with per-sample augmentation into a 784-length buffer.
pub fn render_digit(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), IMG * IMG);
    let segs = &SEGMENTS[digit % CLASSES];
    // augmentation: shift, scale, shear, stroke width
    let sx = rng.range(0.75, 1.1) as f32;
    let sy = rng.range(0.75, 1.1) as f32;
    let tx = rng.range(-0.08, 0.08) as f32;
    let ty = rng.range(-0.08, 0.08) as f32;
    let shear = rng.range(-0.15, 0.15) as f32;
    let width = rng.range(0.05, 0.09) as f32;
    let noise = 0.08f32;
    for row in 0..IMG {
        for col in 0..IMG {
            // map pixel to unit box, inverse-transforming the augmentation
            let px0 = (col as f32 + 0.5) / IMG as f32;
            let py0 = (row as f32 + 0.5) / IMG as f32;
            let px = (px0 - 0.5 - tx) / sx + 0.5;
            let py = (py0 - 0.5 - ty) / sy + 0.5 - shear * (px0 - 0.5);
            let mut dmin = f32::INFINITY;
            for (s, line) in SEG_LINES.iter().enumerate() {
                if segs[s] {
                    dmin = dmin.min(seg_dist(px, py, line));
                }
            }
            // soft stroke: 1 inside, fall off over ~1.5px
            let ink = (1.0 - (dmin - width) / 0.05).clamp(0.0, 1.0);
            let n = rng.normal() as f32 * noise;
            out[row * IMG + col] = (ink + n).clamp(0.0, 1.0);
        }
    }
}

/// Generate the dataset: labels uniform over the 10 digits.
pub fn generate(train: usize, test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5EED_0001);
    let feat = IMG * IMG;
    let mut gen_split = |n: usize| {
        let mut x = vec![0.0f32; n * feat];
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let digit = i % CLASSES; // balanced classes
            render_digit(digit, &mut rng, &mut x[i * feat..(i + 1) * feat]);
            y.push(digit as u32);
        }
        (x, y)
    };
    let (train_x, train_y) = gen_split(train);
    let (test_x, test_y) = gen_split(test);
    Dataset {
        train_x,
        train_y,
        test_x,
        test_y,
        feat_dim: feat,
        classes: CLASSES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_in_range() {
        let mut rng = Rng::new(0);
        let mut buf = vec![0.0f32; IMG * IMG];
        for d in 0..10 {
            render_digit(d, &mut rng, &mut buf);
            assert!(buf.iter().all(|&p| (0.0..=1.0).contains(&p)));
            // a digit must have meaningful ink
            let ink: f32 = buf.iter().sum();
            assert!(ink > 20.0, "digit {d} ink {ink}");
        }
    }

    #[test]
    fn one_and_eight_differ_substantially() {
        let mut rng = Rng::new(1);
        let mut one = vec![0.0f32; IMG * IMG];
        let mut eight = vec![0.0f32; IMG * IMG];
        render_digit(1, &mut rng, &mut one);
        render_digit(8, &mut rng, &mut eight);
        let ink1: f32 = one.iter().sum();
        let ink8: f32 = eight.iter().sum();
        assert!(ink8 > ink1 * 1.8, "8 ({ink8}) should have more ink than 1 ({ink1})");
    }

    #[test]
    fn same_class_varies_between_samples() {
        let mut rng = Rng::new(2);
        let mut a = vec![0.0f32; IMG * IMG];
        let mut b = vec![0.0f32; IMG * IMG];
        render_digit(3, &mut rng, &mut a);
        render_digit(3, &mut rng, &mut b);
        let diff: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 5.0, "augmentation too weak: {diff}");
    }

    #[test]
    fn balanced_labels() {
        let d = generate(100, 20, 3);
        let mut counts = [0usize; 10];
        for &y in &d.train_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn nearest_centroid_separates_classes() {
        // classes must be learnable: nearest-class-mean classifier on raw
        // pixels should beat random (0.1) by a wide margin
        let d = generate(400, 100, 4);
        let feat = d.feat_dim;
        let mut means = vec![vec![0.0f64; feat]; 10];
        let mut counts = [0usize; 10];
        for i in 0..d.train_n() {
            let y = d.train_y[i] as usize;
            counts[y] += 1;
            for (m, &p) in means[y].iter_mut().zip(d.train_row(i)) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.test_n() {
            let row = d.test_row(i);
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(row)
                        .map(|(m, &p)| (m - p as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(row)
                        .map(|(m, &p)| (m - p as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == d.test_y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.test_n() as f64;
        assert!(acc > 0.6, "nearest-centroid accuracy only {acc}");
    }
}
