//! synth-CIFAR: procedural 3x32x32 color-texture classes.
//!
//! Each class is a distinct (orientation, spatial frequency, color palette)
//! sinusoidal grating; samples draw random phase, slight frequency jitter
//! and additive noise. Ten separable but non-trivial classes with the
//! CIFAR-10 tensor shape (3x32x32, CHW flat), per DESIGN.md §Substitutions.

use super::Dataset;
use crate::util::rng::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 10;

/// Class texture parameters: (angle rad, cycles across image, rgb base).
fn class_params(class: usize) -> (f32, f32, [f32; 3]) {
    let angle = (class % 5) as f32 * std::f32::consts::PI / 5.0;
    let freq = if class < 5 { 2.0 } else { 4.5 };
    let palette: [[f32; 3]; 10] = [
        [0.9, 0.2, 0.2],
        [0.2, 0.9, 0.2],
        [0.2, 0.3, 0.9],
        [0.9, 0.8, 0.1],
        [0.8, 0.2, 0.8],
        [0.1, 0.8, 0.8],
        [0.9, 0.5, 0.1],
        [0.5, 0.5, 0.9],
        [0.6, 0.9, 0.4],
        [0.9, 0.4, 0.6],
    ];
    (angle, freq, palette[class % 10])
}

/// Render one sample into a CHW flat buffer of length 3*32*32.
pub fn render_texture(class: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), CHANNELS * IMG * IMG);
    let (angle, freq, rgb) = class_params(class % CLASSES);
    let phase = rng.range(0.0, std::f64::consts::TAU) as f32;
    let fjit = rng.range(0.9, 1.1) as f32;
    let (ca, sa) = (angle.cos(), angle.sin());
    let noise = 0.1f32;
    for row in 0..IMG {
        for col in 0..IMG {
            let x = col as f32 / IMG as f32 - 0.5;
            let y = row as f32 / IMG as f32 - 0.5;
            let u = ca * x + sa * y;
            let wave =
                0.5 + 0.5 * (std::f32::consts::TAU * freq * fjit * u + phase)
                    .sin();
            for ch in 0..CHANNELS {
                let n = rng.normal() as f32 * noise;
                let v = (rgb[ch] * wave + n).clamp(0.0, 1.0);
                out[ch * IMG * IMG + row * IMG + col] = v;
            }
        }
    }
}

pub fn generate(train: usize, test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5EED_0002);
    let feat = CHANNELS * IMG * IMG;
    let mut gen_split = |n: usize| {
        let mut x = vec![0.0f32; n * feat];
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % CLASSES;
            render_texture(class, &mut rng, &mut x[i * feat..(i + 1) * feat]);
            y.push(class as u32);
        }
        (x, y)
    };
    let (train_x, train_y) = gen_split(train);
    let (test_x, test_y) = gen_split(test);
    Dataset {
        train_x,
        train_y,
        test_x,
        test_y,
        feat_dim: feat,
        classes: CLASSES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_in_range_with_energy() {
        let mut rng = Rng::new(0);
        let mut buf = vec![0.0f32; CHANNELS * IMG * IMG];
        for c in 0..CLASSES {
            render_texture(c, &mut rng, &mut buf);
            assert!(buf.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let energy: f32 = buf.iter().sum();
            assert!(energy > 50.0, "class {c} energy {energy}");
        }
    }

    #[test]
    fn classes_have_distinct_color_signature() {
        let mut rng = Rng::new(1);
        let mut mean_rgb = vec![[0.0f64; 3]; CLASSES];
        let mut buf = vec![0.0f32; CHANNELS * IMG * IMG];
        for c in 0..CLASSES {
            render_texture(c, &mut rng, &mut buf);
            for ch in 0..3 {
                let s: f32 =
                    buf[ch * IMG * IMG..(ch + 1) * IMG * IMG].iter().sum();
                mean_rgb[c][ch] = s as f64 / (IMG * IMG) as f64;
            }
        }
        // at least one channel pair differs meaningfully between any two
        // adjacent classes
        for a in 0..CLASSES {
            for b in (a + 1)..CLASSES {
                let diff: f64 = (0..3)
                    .map(|ch| (mean_rgb[a][ch] - mean_rgb[b][ch]).abs())
                    .sum();
                assert!(diff > 0.02, "classes {a},{b} too similar: {diff}");
            }
        }
    }

    #[test]
    fn generate_shapes() {
        let d = generate(30, 10, 7);
        assert_eq!(d.feat_dim, 3072);
        assert_eq!(d.classes, 10);
        assert_eq!(d.train_x.len(), 30 * 3072);
    }
}
