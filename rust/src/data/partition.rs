//! Non-IID data partitioner (paper §VI-A2).
//!
//! "For half of the data samples, we allocate the data samples with the
//! same label into a individual node. For another half of the data samples,
//! we distribute the data samples uniformly." `noniid_fraction` generalizes
//! the paper's 0.5: 0.0 = fully IID, 1.0 = fully by-label.

use crate::util::rng::Rng;

/// Assign train-set indices to `nodes` partitions.
///
/// The by-label share routes samples of label ℓ to node ℓ mod nodes; the
/// rest are shuffled uniformly. Every node is guaranteed at least one
/// sample (the engine needs a non-empty sampler).
pub fn partition_noniid(
    labels: &[u32],
    nodes: usize,
    noniid_fraction: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    assert!(nodes > 0);
    assert!((0.0..=1.0).contains(&noniid_fraction));
    let mut rng = Rng::new(seed ^ 0x5EED_0004);
    let n = labels.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let cut = ((n as f64) * noniid_fraction).round() as usize;
    let mut parts = vec![Vec::new(); nodes];
    // by-label share
    for &i in &order[..cut] {
        let node = labels[i] as usize % nodes;
        parts[node].push(i);
    }
    // uniform share
    for (k, &i) in order[cut..].iter().enumerate() {
        parts[k % nodes].push(i);
    }
    // guarantee non-empty: steal from the largest node
    for victim in 0..nodes {
        if parts[victim].is_empty() {
            let donor = (0..nodes)
                .max_by_key(|&j| parts[j].len())
                .expect("nodes > 0");
            if parts[donor].len() > 1 {
                let idx = parts[donor].pop().unwrap();
                parts[victim].push(idx);
            }
        }
    }
    parts
}

/// Label histogram of a partition — used by tests and the CLI `inspect`.
pub fn label_histogram(
    labels: &[u32],
    part: &[usize],
    classes: usize,
) -> Vec<usize> {
    let mut h = vec![0usize; classes];
    for &i in part {
        h[labels[i] as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels_balanced(n: usize, classes: usize) -> Vec<u32> {
        (0..n).map(|i| (i % classes) as u32).collect()
    }

    #[test]
    fn covers_all_indices_exactly_once() {
        let labels = labels_balanced(100, 10);
        let parts = partition_noniid(&labels, 10, 0.5, 0);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn iid_partition_roughly_balanced() {
        let labels = labels_balanced(1000, 10);
        let parts = partition_noniid(&labels, 10, 0.0, 1);
        for p in &parts {
            assert_eq!(p.len(), 100);
        }
        // each node sees most classes
        for p in &parts {
            let h = label_histogram(&labels, p, 10);
            let present = h.iter().filter(|&&c| c > 0).count();
            assert!(present >= 8, "{h:?}");
        }
    }

    #[test]
    fn fully_noniid_concentrates_labels() {
        let labels = labels_balanced(1000, 10);
        let parts = partition_noniid(&labels, 10, 1.0, 2);
        for (node, p) in parts.iter().enumerate() {
            let h = label_histogram(&labels, p, 10);
            // all mass on label == node
            assert_eq!(h[node], p.len(), "node {node}: {h:?}");
        }
    }

    #[test]
    fn paper_half_split_skews_but_covers() {
        let labels = labels_balanced(1000, 10);
        let parts = partition_noniid(&labels, 10, 0.5, 3);
        for (node, p) in parts.iter().enumerate() {
            let h = label_histogram(&labels, p, 10);
            // own label over-represented vs perfect balance
            assert!(
                h[node] > p.len() / 10,
                "node {node} own-label {h:?}"
            );
        }
    }

    #[test]
    fn more_nodes_than_samples_still_nonempty() {
        let labels = labels_balanced(5, 3);
        let parts = partition_noniid(&labels, 4, 0.5, 4);
        let nonempty = parts.iter().filter(|p| !p.is_empty()).count();
        assert!(nonempty >= 4.min(labels.len()), "{parts:?}");
    }

    #[test]
    fn fewer_nodes_than_classes() {
        let labels = labels_balanced(60, 10);
        let parts = partition_noniid(&labels, 3, 1.0, 5);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 60);
        for p in &parts {
            assert!(!p.is_empty());
        }
    }
}
