//! Gaussian-blob classification data — the fast dataset for unit tests,
//! property tests and quick sweeps.

use super::Dataset;
use crate::util::rng::Rng;

/// Class means drawn once (seeded), samples = mean + N(0, 0.3).
pub fn generate(
    train: usize,
    test: usize,
    dim: usize,
    classes: usize,
    seed: u64,
) -> Dataset {
    assert!(classes >= 2 && dim >= 1);
    let mut rng = Rng::new(seed ^ 0x5EED_0003);
    let means: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..dim).map(|_| rng.normal_ms(0.0, 1.5) as f32).collect())
        .collect();
    let mut gen_split = |n: usize| {
        let mut x = vec![0.0f32; n * dim];
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..dim {
                x[i * dim + j] =
                    means[c][j] + rng.normal_ms(0.0, 0.3) as f32;
            }
            y.push(c as u32);
        }
        (x, y)
    };
    let (train_x, train_y) = gen_split(train);
    let (test_x, test_y) = gen_split(test);
    Dataset { train_x, train_y, test_x, test_y, feat_dim: dim, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = generate(40, 12, 6, 4, 0);
        assert_eq!(d.feat_dim, 6);
        assert_eq!(d.classes, 4);
        assert_eq!(d.train_n(), 40);
        assert!(d.train_y.iter().all(|&y| y < 4));
    }

    #[test]
    fn blobs_linearly_separable_enough() {
        let d = generate(200, 100, 8, 3, 1);
        // nearest class mean classifier should be near-perfect at std 0.3
        let mut means = vec![vec![0.0f64; 8]; 3];
        let mut counts = [0usize; 3];
        for i in 0..d.train_n() {
            let y = d.train_y[i] as usize;
            counts[y] += 1;
            for (m, &v) in means[y].iter_mut().zip(d.train_row(i)) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            m.iter_mut().for_each(|v| *v /= c as f64);
        }
        let mut correct = 0;
        for i in 0..d.test_n() {
            let row = d.test_row(i);
            let pred = (0..3)
                .min_by(|&a, &b| {
                    let da: f64 = means[a].iter().zip(row)
                        .map(|(m, &p)| (m - p as f64).powi(2)).sum();
                    let db: f64 = means[b].iter().zip(row)
                        .map(|(m, &p)| (m - p as f64).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == d.test_y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct as f64 / d.test_n() as f64 > 0.9);
    }
}
