//! Micro-bench: quantizer hot paths — quantize / dequantize / encode /
//! decode throughput per quantizer and vector size. The L3 perf targets in
//! DESIGN.md §Perf are tracked here.
//!
//!   cargo bench --bench micro_quant

use lmdfl::bench::{black_box, Bencher};
use lmdfl::quant::kernels;
use lmdfl::quant::{
    build_quantizer, codec, wire, AlqQuantizer, LloydMaxQuantizer,
    NaturalQuantizer, QsgdQuantizer, Quantizer,
};
use lmdfl::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0);
    println!("avx2 kernels: {}", kernels::avx2_enabled());

    for &d in &[10_000usize, 100_000, 1_000_000] {
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        println!("--- d = {d} ---");

        let mut lm = LloydMaxQuantizer::new(64, 12);
        b.run_elems(&format!("lloyd_max s=64 quantize d={d}"), d as u64, || {
            black_box(lm.quantize(&v, &mut rng));
        });

        let mut lm4 = LloydMaxQuantizer::new(4, 12);
        b.run_elems(&format!("lloyd_max s=4 quantize d={d}"), d as u64, || {
            black_box(lm4.quantize(&v, &mut rng));
        });

        let mut qsgd = QsgdQuantizer::new(64);
        b.run_elems(&format!("qsgd s=64 quantize d={d}"), d as u64, || {
            black_box(qsgd.quantize(&v, &mut rng));
        });

        let mut nat = NaturalQuantizer::new(16);
        b.run_elems(&format!("natural s=16 quantize d={d}"), d as u64, || {
            black_box(nat.quantize(&v, &mut rng));
        });

        let mut alq = AlqQuantizer::new(16);
        b.run_elems(&format!("alq s=16 quantize d={d}"), d as u64, || {
            black_box(alq.quantize(&v, &mut rng));
        });

        // codec
        let msg = lm.quantize(&v, &mut rng);
        b.run_elems(&format!("codec encode d={d}"), d as u64, || {
            black_box(codec::encode(&msg));
        });
        let bytes = codec::encode(&msg);
        b.run_elems(&format!("codec decode d={d}"), d as u64, || {
            black_box(codec::decode(&bytes, |_| unreachable!()).unwrap());
        });

        // the versioned transport frame the engines actually broadcast
        let header = wire::WireHeader::new(
            wire::QuantTag::LloydMax,
            0,
            1,
            7,
            msg.s(),
        );
        let mut wire_buf: Vec<u8> = Vec::new();
        b.run_elems(&format!("wire encode d={d}"), d as u64, || {
            wire_buf = wire::encode_with_buf(
                &header,
                &msg,
                std::mem::take(&mut wire_buf),
            );
            black_box(&wire_buf);
        });
        let wire_bytes = wire::encode(&header, &msg);
        let mut wire_cache = wire::ImpliedCache::new();
        let mut wire_out = lmdfl::quant::QuantizedVector::empty();
        b.run_elems(&format!("wire decode d={d}"), d as u64, || {
            wire::decode_into(&wire_bytes, &mut wire_cache, &mut wire_out)
                .unwrap();
            black_box(&wire_out);
        });
        let mut buf = vec![0.0f32; d];
        b.run_elems(&format!("dequantize_into d={d}"), d as u64, || {
            msg.dequantize_into(&mut buf);
            black_box(&buf);
        });
    }

    // ---- sparse wire bodies (WIRE_VERSION 2) ---------------------------
    // top-k keeps 1% of coordinates: the frame is ~k entries, not d, so
    // throughput is measured per input element to keep rows comparable
    println!("--- sparse wire codec (top-k keep=1%) ---");
    for &d in &[100_000usize, 1_000_000] {
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut topk = lmdfl::quant::TopKQuantizer::new(0.01);
        let msg = topk.quantize(&v, &mut rng);
        assert!(
            codec::sparse_nnz(&msg).is_some(),
            "top-k message should take the sparse body"
        );
        let header = wire::WireHeader::new(
            wire::QuantTag::TopK,
            0,
            1,
            7,
            msg.s(),
        );
        let mut sparse_buf: Vec<u8> = Vec::new();
        b.run_elems(&format!("wire encode sparse d={d}"), d as u64, || {
            sparse_buf = wire::encode_with_buf(
                &header,
                &msg,
                std::mem::take(&mut sparse_buf),
            );
            black_box(&sparse_buf);
        });
        let sparse_bytes = wire::encode(&header, &msg);
        println!(
            "    sparse frame: {} bytes (dense form would be {})",
            sparse_bytes.len(),
            wire::HEADER_BYTES
                + lmdfl::quant::bits::stream_bytes(codec::encoded_bits(
                    d,
                    msg.s(),
                    false,
                )),
        );
        let mut cache = wire::ImpliedCache::new();
        let mut out = lmdfl::quant::QuantizedVector::empty();
        b.run_elems(&format!("wire decode sparse d={d}"), d as u64, || {
            wire::decode_into(&sparse_bytes, &mut cache, &mut out)
                .unwrap();
            black_box(&out);
        });
    }

    // level-count sensitivity of the LM fit
    println!("--- lloyd-max fit cost vs s (d = 100k) ---");
    let v: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32).collect();
    for &s in &[4usize, 16, 64, 256, 1024] {
        let mut q = build_quantizer(
            &lmdfl::config::QuantizerKind::LloydMax { s, iters: 12 });
        b.run_elems(&format!("lloyd_max quantize s={s}"), 100_000, || {
            black_box(q.quantize(&v, &mut rng));
        });
    }

    // allocation-free path vs the allocating one (same math; the into
    // variant reuses message buffers — the engines' hot path)
    println!("--- quantize vs quantize_into (d = 100k) ---");
    use lmdfl::quant::QuantizedVector;
    let mut lm = LloydMaxQuantizer::new(64, 12);
    b.run_elems("lloyd_max s=64 quantize (alloc)", 100_000, || {
        black_box(lm.quantize(&v, &mut rng));
    });
    let mut msg = QuantizedVector::empty();
    b.run_elems("lloyd_max s=64 quantize_into", 100_000, || {
        lm.quantize_into(&v, &mut rng, &mut msg);
        black_box(&msg);
    });

    // ---- batch kernels vs the in-tree scalar reference -----------------
    // (assign / pack / unpack / dequantize-accumulate; the CI bench-smoke
    // regression gate compares the d = 1M pack rows)
    println!("--- batch kernels vs scalar reference ---");
    for &d in &[10_000usize, 1_000_000] {
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let norm = lmdfl::util::stats::l2_norm(&v) as f32;

        // Lloyd-Max-shaped deterministic assignment
        let mut r = Vec::new();
        kernels::normalized_magnitudes_into(&v, norm, &mut r);
        let r_max = r.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
        let s = 64usize;
        let inner: Vec<f32> =
            (1..s).map(|j| j as f32 / s as f32 * r_max).collect();
        const BINS: usize = 8192;
        let mut lut = Vec::new();
        kernels::build_count_lut(&inner, r_max, BINS, &mut lut);
        let scale = BINS as f32 / r_max;
        let mut idx = Vec::new();
        b.run_elems(&format!("kernel lm_assign d={d}"), d as u64, || {
            kernels::assign_lut_slice(&inner, &lut, scale, &r, &mut idx);
            black_box(&idx);
        });
        b.run_elems(&format!("scalar lm_assign d={d}"), d as u64, || {
            kernels::reference::assign_lut_slice(
                &inner, &lut, scale, &r, &mut idx,
            );
            black_box(&idx);
        });

        // pack / unpack at s = 16 (4-bit indices + 1 sign bit per elem)
        let mut rng2 = Rng::new(1);
        let vals: Vec<u32> =
            (0..d).map(|_| (rng2.next_u64() & 0xF) as u32).collect();
        let signs: Vec<bool> =
            (0..d).map(|_| rng2.next_u64() & 1 == 1).collect();
        let mut buf: Vec<u8> = Vec::new();
        b.run_elems(&format!("kernel pack s=16 d={d}"), d as u64, || {
            buf.clear();
            let st = kernels::pack_bools(&signs, 0, 0, &mut buf);
            let st = kernels::pack_values(&vals, 4, st.0, st.1, &mut buf);
            if st.1 > 0 {
                buf.push(st.0 as u8);
            }
            black_box(&buf);
        });
        let packed = buf.clone();
        b.run_elems(&format!("scalar pack s=16 d={d}"), d as u64, || {
            buf.clear();
            let st =
                kernels::reference::pack_bools(&signs, 0, 0, &mut buf);
            let st = kernels::reference::pack_values(
                &vals, 4, st.0, st.1, &mut buf,
            );
            if st.1 > 0 {
                buf.push(st.0 as u8);
            }
            black_box(&buf);
        });
        let mut out_signs = Vec::new();
        let mut out_vals = Vec::new();
        b.run_elems(&format!("kernel unpack s=16 d={d}"), d as u64, || {
            out_signs.clear();
            out_vals.clear();
            let st = kernels::unpack_bools(
                &packed, 0, 0, 0, d, &mut out_signs,
            )
            .unwrap();
            kernels::unpack_values(
                &packed, st.0, st.1, st.2, 4, d, &mut out_vals,
            )
            .unwrap();
            black_box((&out_signs, &out_vals));
        });
        b.run_elems(&format!("scalar unpack s=16 d={d}"), d as u64, || {
            out_signs.clear();
            out_vals.clear();
            let st = kernels::reference::unpack_bools(
                &packed, 0, 0, 0, d, &mut out_signs,
            )
            .unwrap();
            kernels::reference::unpack_values(
                &packed, st.0, st.1, st.2, 4, d, &mut out_vals,
            )
            .unwrap();
            black_box((&out_signs, &out_vals));
        });

        // fused dequantize-accumulate (gossip estimate recursion)
        let levels: Vec<f32> = (0..16).map(|j| j as f32 / 15.0).collect();
        let mut acc = vec![0.0f32; d];
        b.run_elems(&format!("kernel dequant_acc d={d}"), d as u64, || {
            kernels::dequantize_accumulate(
                norm, &signs, &vals, &levels, &mut acc,
            );
            black_box(&acc);
        });
        b.run_elems(&format!("scalar dequant_acc d={d}"), d as u64, || {
            kernels::reference::dequantize_accumulate(
                norm, &signs, &vals, &levels, &mut acc,
            );
            black_box(&acc);
        });
    }

    b.finish("micro_quant");
}
