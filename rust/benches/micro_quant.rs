//! Micro-bench: quantizer hot paths — quantize / dequantize / encode /
//! decode throughput per quantizer and vector size. The L3 perf targets in
//! DESIGN.md §Perf are tracked here.
//!
//!   cargo bench --bench micro_quant

use lmdfl::bench::{black_box, Bencher};
use lmdfl::quant::{
    build_quantizer, codec, AlqQuantizer, LloydMaxQuantizer,
    NaturalQuantizer, QsgdQuantizer, Quantizer,
};
use lmdfl::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0);

    for &d in &[10_000usize, 100_000, 1_000_000] {
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        println!("--- d = {d} ---");

        let mut lm = LloydMaxQuantizer::new(64, 12);
        b.run_elems(&format!("lloyd_max s=64 quantize d={d}"), d as u64, || {
            black_box(lm.quantize(&v, &mut rng));
        });

        let mut lm4 = LloydMaxQuantizer::new(4, 12);
        b.run_elems(&format!("lloyd_max s=4 quantize d={d}"), d as u64, || {
            black_box(lm4.quantize(&v, &mut rng));
        });

        let mut qsgd = QsgdQuantizer::new(64);
        b.run_elems(&format!("qsgd s=64 quantize d={d}"), d as u64, || {
            black_box(qsgd.quantize(&v, &mut rng));
        });

        let mut nat = NaturalQuantizer::new(16);
        b.run_elems(&format!("natural s=16 quantize d={d}"), d as u64, || {
            black_box(nat.quantize(&v, &mut rng));
        });

        let mut alq = AlqQuantizer::new(16);
        b.run_elems(&format!("alq s=16 quantize d={d}"), d as u64, || {
            black_box(alq.quantize(&v, &mut rng));
        });

        // codec
        let msg = lm.quantize(&v, &mut rng);
        b.run_elems(&format!("codec encode d={d}"), d as u64, || {
            black_box(codec::encode(&msg));
        });
        let bytes = codec::encode(&msg);
        b.run_elems(&format!("codec decode d={d}"), d as u64, || {
            black_box(codec::decode(&bytes, |_| unreachable!()).unwrap());
        });
        let mut buf = vec![0.0f32; d];
        b.run_elems(&format!("dequantize_into d={d}"), d as u64, || {
            msg.dequantize_into(&mut buf);
            black_box(&buf);
        });
    }

    // level-count sensitivity of the LM fit
    println!("--- lloyd-max fit cost vs s (d = 100k) ---");
    let v: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32).collect();
    for &s in &[4usize, 16, 64, 256, 1024] {
        let mut q = build_quantizer(
            &lmdfl::config::QuantizerKind::LloydMax { s, iters: 12 });
        b.run_elems(&format!("lloyd_max quantize s={s}"), 100_000, || {
            black_box(q.quantize(&v, &mut rng));
        });
    }

    // allocation-free path vs the allocating one (same math; the into
    // variant reuses message buffers — the engines' hot path)
    println!("--- quantize vs quantize_into (d = 100k) ---");
    use lmdfl::quant::QuantizedVector;
    let mut lm = LloydMaxQuantizer::new(64, 12);
    b.run_elems("lloyd_max s=64 quantize (alloc)", 100_000, || {
        black_box(lm.quantize(&v, &mut rng));
    });
    let mut msg = QuantizedVector::empty();
    b.run_elems("lloyd_max s=64 quantize_into", 100_000, || {
        lm.quantize_into(&v, &mut rng, &mut msg);
        black_box(&msg);
    });

    b.finish("micro_quant");
}
