//! Fig. 6(a-d) bench: LM-DFL vs no-quant / ALQ / QSGD on synth-MNIST.
//!
//!   cargo bench --bench fig6_mnist          (quick scale)
//!   LMDFL_FULL=1 cargo bench --bench fig6_mnist

use lmdfl::experiments::{fig6, Scale};

fn main() {
    let scale = Scale::from_env();
    println!("=== Fig. 6 (a-d): synth-MNIST, {scale:?} scale ===");
    let curves = fig6::run_mnist(scale).expect("fig6 mnist");
    println!("{}", fig6::render_panels(&curves, 100e6));
    summary(&curves);
}

fn summary(curves: &[lmdfl::experiments::Curve]) {
    println!("headline ordering checks:");
    let last = |label: &str| {
        curves
            .iter()
            .find(|c| c.label.ends_with(label))
            .unwrap()
            .log
            .records
            .last()
            .unwrap()
            .clone()
    };
    let (lm, alq, qsgd, noq) = (
        last("LM-DFL"),
        last("ALQ"),
        last("QSGD"),
        last("no-quant"),
    );
    println!(
        "  distortion: LM {:.5} <= ALQ {:.5} ? {}   LM <= QSGD {:.5} ? {}",
        lm.distortion,
        alq.distortion,
        lm.distortion <= alq.distortion * 1.1,
        qsgd.distortion,
        lm.distortion <= qsgd.distortion,
    );
    println!(
        "  bits/link:  LM {} << no-quant {} ? {}",
        lm.bits_per_link,
        noq.bits_per_link,
        lm.bits_per_link * 2 < noq.bits_per_link,
    );
}
