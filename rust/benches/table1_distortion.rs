//! Table I bench: quantization distortion of QSGD / natural / ALQ / LM-DFL
//! vs the paper's analytical bounds, across d, s and value distributions.
//!
//!   cargo bench --bench table1_distortion
//!   LMDFL_FULL=1 cargo bench ... for the full grid

use lmdfl::experiments::table1;
use lmdfl::experiments::Scale;

fn main() {
    let scale = Scale::from_env();
    let (ds, ss, trials) = match scale {
        Scale::Quick => (vec![1000usize, 10_000], vec![4usize, 16, 64], 2),
        Scale::Full => (
            vec![1000usize, 10_000, 100_000],
            vec![4usize, 16, 64, 256],
            5,
        ),
    };
    println!("=== Table I: normalized quantization distortion ===");
    let mut rows = Vec::new();
    for &d in &ds {
        for &s in &ss {
            for dist in ["gaussian", "laplace", "gradient"] {
                rows.extend(table1::measure(d, s, dist, trials, 42));
            }
        }
    }
    println!("{}", table1::render(&rows));

    // headline check: LM vs QSGD distortion at same s
    println!(
        "LM vs QSGD measured-distortion ratio (expect roughly an order of \
         magnitude):"
    );
    for &s in &ss {
        let rows = table1::measure(10_000, s, "gaussian", 3, 7);
        let get = |name: &str| {
            rows.iter().find(|r| r.quantizer == name).unwrap().measured
        };
        println!(
            "  s={s:4}: QSGD/LM = {:.1}x   ALQ/LM = {:.1}x",
            get("QSGD") / get("LM-DFL"),
            get("ALQ") / get("LM-DFL"),
        );
    }
}
