//! Micro-bench: asynchronous gossip engine throughput + sync-vs-async
//! virtual-time-to-loss.
//!
//! Measures (a) end-to-end async engine runs (events/s over the full
//! state-machine loop: local steps, quantize, broadcast, quorum, mix)
//! at 8/16/32 nodes on a straggler-heavy torus, (b) the virtual
//! time each engine needs to reach a shared target loss — the headline
//! number of the `async-torus-16` preset, reported here per fleet
//! size — and (c) the PR 8 scale rows: full async runs at 1024/4096
//! nodes (random 4-regular) and 10k nodes (torus) with node records
//! streamed to a sink. Reports into the shared `BENCH_*.json`
//! pipeline (including peak RSS); CI's bench-smoke job gates the
//! scale rows' events/s and the process memory ceiling.
//!
//!   cargo bench --bench micro_agossip
//!   LMDFL_BENCH_QUICK=1 LMDFL_BENCH_JSON=bench-reports \
//!       cargo bench --bench micro_agossip   # CI smoke + JSON artifact

use lmdfl::agossip::{AsyncConfig, AsyncGossipEngine, WaitPolicy};
use lmdfl::bench::{black_box, Bencher};
use lmdfl::config::{
    BackendKind, DatasetKind, EngineMode, ExperimentConfig, LrSchedule,
    Parallelism, QuantizerKind, TopologyKind,
};
use lmdfl::experiments::{fig_time, Scale};
use lmdfl::simnet::{ComputeModel, LinkModel, NetworkConfig};

fn network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.005,
            bandwidth_bps: 2e6,
            jitter_s: 0.001,
            drop_prob: 0.0,
        },
        link_hetero_spread: 0.5,
        compute: ComputeModel {
            base_step_s: 2e-3,
            hetero_spread: 0.5,
            straggler_prob: 0.25,
            straggler_slowdown: 8.0,
        },
        churn: Default::default(),
    }
}

fn cfg(nodes: usize, mode: EngineMode) -> ExperimentConfig {
    ExperimentConfig {
        name: "micro_agossip".into(),
        seed: 9,
        nodes,
        tau: 4,
        rounds: 6,
        batch_size: 16,
        lr: LrSchedule::fixed(0.05),
        topology: TopologyKind::Torus,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 8 },
        dataset: DatasetKind::Blobs {
            train: 30 * nodes,
            test: 64,
            dim: 16,
            classes: 4,
        },
        backend: BackendKind::RustMlp { hidden: vec![32] },
        noniid_fraction: 0.5,
        link_bps: 2e6,
        eval_every: 1,
        parallelism: Parallelism::Off,
        network: Some(network()),
        mode,
        encoding: Default::default(),
        agossip: Some(AsyncConfig {
            wait_for: WaitPolicy::Quorum { k: 2 },
            staleness_lambda: 0.5,
            quorum_timeout_s: 0.5,
        }),
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

fn main() {
    let mut b = Bencher::new();

    for &nodes in &[8usize, 16, 32] {
        // events per full run, measured once on a probe
        let events_per_run = {
            let probe = AsyncGossipEngine::new(&cfg(
                nodes,
                EngineMode::Async,
            ))
            .unwrap()
            .run()
            .unwrap();
            probe.events
        };

        b.run_elems(
            &format!("agossip run n={nodes} torus"),
            events_per_run,
            || {
                let log = AsyncGossipEngine::new(&cfg(
                    nodes,
                    EngineMode::Async,
                ))
                .unwrap()
                .run()
                .unwrap();
                black_box(log.events);
            },
        );

        // virtual-time-to-loss: one sync + one async run on the same
        // fabric seed, shared target just above the worse final loss
        let sync_log = lmdfl::dfl::Trainer::run_simulated(&cfg(
            nodes,
            EngineMode::Sync,
        ))
        .unwrap();
        let async_log = lmdfl::dfl::Trainer::run_simulated(&cfg(
            nodes,
            EngineMode::Async,
        ))
        .unwrap();
        let target = sync_log
            .last_loss()
            .unwrap()
            .max(async_log.last_loss().unwrap())
            * 1.1;
        let t_sync = sync_log.virtual_secs_to_loss(target);
        let t_async = async_log.virtual_secs_to_loss(target);
        println!(
            "n={nodes}: {events_per_run} events/run; virtual secs to \
             loss {target:.4}: sync {t_sync:?} vs async {t_async:?}",
        );
    }

    // large-fleet scale rows: the async engine end-to-end on the PR 8
    // preset shapes (tiny model, sparse eval, streamed node records so
    // resident memory stays at the fleet's working set). CI's
    // bench-smoke job gates these rows at ≥1M events/s and checks the
    // report's peak RSS.
    for &(nodes, name) in &[
        (1024usize, "random-regular-1024"),
        (4096, "random-regular-4096"),
        (10_000, "torus-10k"),
    ] {
        let mut scfg =
            fig_time::scale_config(name, nodes, true, Scale::Quick);
        scfg.rounds = 2;
        scfg.network = Some(fig_time::scale_network());
        let events_per_run = {
            let mut probe = AsyncGossipEngine::new(&scfg).unwrap();
            probe.stream_node_records(Box::new(std::io::sink()));
            probe.run().unwrap().events
        };
        b.run_elems(
            &format!("agossip scale n={nodes} {name}"),
            events_per_run,
            || {
                let mut eng = AsyncGossipEngine::new(&scfg).unwrap();
                eng.stream_node_records(Box::new(std::io::sink()));
                black_box(eng.run().unwrap().events);
            },
        );
        println!("n={nodes} {name}: {events_per_run} events/run");
    }

    if let Some(rss) = lmdfl::bench::peak_rss_bytes() {
        println!("peak rss: {:.1} MiB", rss as f64 / (1 << 20) as f64);
    }
    b.finish("micro_agossip");
}
