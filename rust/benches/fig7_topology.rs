//! Fig. 7 bench: LM-DFL test accuracy under ζ ∈ {0, 0.87, 1} topologies.
//!
//!   cargo bench --bench fig7_topology
//!   LMDFL_FULL=1 cargo bench --bench fig7_topology

use lmdfl::experiments::{fig7, Scale};

fn main() {
    println!("=== Fig. 7: topology impact ===");
    for (label, zeta) in fig7::zetas(10) {
        println!(
            "{label:<26} zeta={zeta:.4} alpha={:.3}",
            lmdfl::linalg::eigen::alpha_of_zeta(zeta)
        );
    }
    let curves = fig7::run(Scale::from_env()).expect("fig7");
    println!("{}", fig7::render(&curves));
    let accs: Vec<f64> = curves
        .iter()
        .map(|c| c.log.final_accuracy().unwrap_or(f64::NAN))
        .collect();
    println!(
        "final accuracy: full {:.3} >= ring {:.3} >= disconnected {:.3} ? {}",
        accs[0],
        accs[1],
        accs[2],
        accs[0] >= accs[1] - 0.03 && accs[1] >= accs[2] - 0.03,
    );
}
