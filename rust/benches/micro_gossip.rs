//! Micro-bench: end-to-end gossip round cost on the matrix engine and the
//! threaded runtime — isolates L3 coordination overhead from model compute.
//!
//!   cargo bench --bench micro_gossip

use lmdfl::bench::{black_box, Bencher};
use lmdfl::config::{
    DatasetKind, ExperimentConfig, LrSchedule, QuantizerKind, TopologyKind,
};
use lmdfl::dfl::{NetOptions, Trainer};

fn cfg(nodes: usize, hidden: usize, quant: QuantizerKind) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench".into(),
        seed: 3,
        nodes,
        tau: 4,
        rounds: 4,
        batch_size: 32,
        lr: LrSchedule::fixed(0.05),
        topology: TopologyKind::Ring,
        quantizer: quant,
        dataset: DatasetKind::Blobs {
            train: 64 * nodes,
            test: 64,
            dim: 64,
            classes: 10,
        },
        backend: lmdfl::config::BackendKind::RustMlp {
            hidden: vec![hidden],
        },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1000, // exclude eval cost from the round timing
        parallelism: lmdfl::config::Parallelism::Auto,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

fn main() {
    let mut b = Bencher::new();
    for &nodes in &[4usize, 10, 20] {
        for quant in [
            QuantizerKind::Full,
            QuantizerKind::Qsgd { s: 16 },
            QuantizerKind::LloydMax { s: 16, iters: 12 },
        ] {
            let c = cfg(nodes, 128, quant.clone());
            let mut trainer = Trainer::build(&c).unwrap();
            let mut k = 0usize;
            b.run(
                &format!("matrix round n={nodes} {}", quant.name()),
                || {
                    black_box(
                        trainer.engine_mut().round(k).unwrap());
                    k += 1;
                },
            );
        }
    }

    // threaded runtime: full short runs (includes thread setup)
    for &nodes in &[4usize, 10] {
        let c = cfg(nodes, 64, QuantizerKind::LloydMax { s: 16, iters: 8 });
        b.run(&format!("threaded 4-round run n={nodes}"), || {
            black_box(
                Trainer::run_threaded(
                    &c,
                    NetOptions {
                        link: lmdfl::simnet::LinkModel::ideal(),
                        eval_every: 1000,
                    },
                )
                .unwrap(),
            );
        });
    }

    b.finish("micro_gossip");
}
