//! Micro-bench: the `lmdfl analyse` hot loop — trace parsing and
//! rank-merged aggregation throughput.
//!
//! A sweep's analyse pass reads every cell's JSONL trace through
//! `obs::export::parse_trace` and rolls it up with `obs::aggregate`;
//! on wide sweeps that is the dominant cost after the cells
//! themselves. This bench records a realistic trace through the
//! public probe API (spans, virtual spans, counters, histograms),
//! then measures lines/s through the parser and records/s through
//! each aggregation table. Reports into the shared `BENCH_*.json`
//! pipeline (including peak RSS).
//!
//!   cargo bench --bench micro_obs
//!   LMDFL_BENCH_QUICK=1 LMDFL_BENCH_JSON=bench-reports \
//!       cargo bench --bench micro_obs   # CI smoke + JSON artifact

use lmdfl::bench::{black_box, Bencher};
use lmdfl::obs;

/// Record `rounds` rounds' worth of probes into a JSONL trace file
/// and hand back its text.
fn recorded_trace(rounds: usize) -> String {
    let dir = std::env::temp_dir().join(format!(
        "lmdfl-micro-obs-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    obs::start(
        &obs::ObserveConfig {
            trace_path: Some(path.display().to_string()),
            chrome_path: None,
        },
        0,
    );
    let keys: Vec<String> =
        (0..8).map(|k| format!("0->{k}")).collect();
    for round in 0..rounds {
        {
            let _g = obs::span("round");
            let _inner = obs::span("mix");
            black_box(round);
        }
        obs::vspan(
            "virtual_round",
            round % 16,
            (round as u64) * 1_000,
            (round as u64) * 1_000 + 750,
        );
        for key in &keys {
            obs::counter("frame_send", key, 1);
        }
        obs::hist("wait_ns", ((round as u64) % 4096) + 1);
    }
    obs::stop().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    text
}

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("LMDFL_BENCH_QUICK").is_ok();
    let rounds = if quick { 2_000 } else { 20_000 };
    let text = recorded_trace(rounds);
    let lines = text.lines().count();

    b.run_elems(
        &format!("parse_trace {lines} lines"),
        lines,
        || {
            let tf = obs::export::parse_trace(&text).unwrap();
            black_box(tf.lines);
        },
    );

    let tf = obs::export::parse_trace(&text).unwrap();
    b.run_elems(
        &format!("aggregate spans ({} recs)", tf.spans.len()),
        tf.spans.len(),
        || {
            let rows = obs::aggregate::spans(&tf);
            black_box(rows.len());
        },
    );
    b.run_elems(
        &format!("aggregate counters ({} recs)", tf.counters.len()),
        tf.counters.len(),
        || {
            let rows = obs::aggregate::counters(&tf);
            black_box(rows.len());
        },
    );
    b.run_elems(
        &format!("aggregate hists ({} recs)", tf.hists.len()),
        tf.hists.len(),
        || {
            let rows = obs::aggregate::hists(&tf);
            black_box(rows.len());
        },
    );

    if let Some(rss) = lmdfl::bench::peak_rss_bytes() {
        println!(
            "peak rss: {:.1} MiB",
            rss as f64 / (1 << 20) as f64
        );
    }
    b.finish("micro_obs");
}
