//! Fig. 6(e-h) bench: LM-DFL vs no-quant / ALQ / QSGD on synth-CIFAR
//! (paper settings: s = 100, lower lr).
//!
//!   cargo bench --bench fig6_cifar
//!   LMDFL_FULL=1 cargo bench --bench fig6_cifar

use lmdfl::experiments::{fig6, Scale};

fn main() {
    let scale = Scale::from_env();
    println!("=== Fig. 6 (e-h): synth-CIFAR, {scale:?} scale ===");
    let curves = fig6::run_cifar(scale).expect("fig6 cifar");
    println!("{}", fig6::render_panels(&curves, 100e6));
}
