//! Fig. 4 bench: loss vs communicated bits under ascending / fixed /
//! descending numbers of quantization levels (the §V motivation).
//!
//!   cargo bench --bench fig4_adaptive_s
//!   LMDFL_FULL=1 cargo bench --bench fig4_adaptive_s

use lmdfl::experiments::{fig4, fig8, Scale};

fn main() {
    println!("=== Fig. 4: adaptive vs fixed s (loss vs bits) ===");
    let curves = fig4::run_mnist(Scale::from_env()).expect("fig4");
    println!("{}", fig8::render_loss_vs_bits(&curves));
    println!("{}", fig8::render_bits_per_element(&curves));
    let target = curves
        .iter()
        .map(|c| c.log.records.last().unwrap().loss)
        .fold(f64::MIN, f64::max)
        * 1.1;
    println!("{}", fig8::bits_to_target(&curves, target));
}
