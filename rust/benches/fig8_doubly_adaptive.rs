//! Fig. 8 bench: doubly-adaptive DFL vs QSGD 2/4/8-bit, fixed + variable
//! learning rates, with the bits-per-element schedule (panels a-f).
//!
//!   cargo bench --bench fig8_doubly_adaptive
//!   LMDFL_FULL=1 cargo bench --bench fig8_doubly_adaptive

use lmdfl::experiments::{fig8, Curve, Scale};

fn main() {
    let scale = Scale::from_env();
    type Runner = fn(Scale, bool) -> anyhow::Result<Vec<Curve>>;
    let runners: [(&str, Runner); 2] = [
        ("synth-MNIST", fig8::run_mnist),
        ("synth-CIFAR", fig8::run_cifar),
    ];
    for (dataset, runner) in runners {
        for variable_lr in [false, true] {
            let tag = if variable_lr { "variable lr" } else { "fixed lr" };
            println!("=== Fig. 8: {dataset}, {tag} ===");
            let curves = runner(scale, variable_lr).expect("fig8");
            println!("{}", fig8::render_loss_vs_bits(&curves));
            println!("{}", fig8::render_bits_per_element(&curves));
            let target = curves
                .iter()
                .map(|c| c.log.records.last().unwrap().loss)
                .fold(f64::MIN, f64::max)
                * 1.1;
            println!("{}", fig8::bits_to_target(&curves, target));
        }
    }
}
