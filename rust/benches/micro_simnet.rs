//! Micro-bench: simnet event throughput.
//!
//! Measures (a) the raw event-queue schedule/pop rate and (b) full
//! fabric rounds (links + compute + stragglers) at 16/64 nodes on a
//! torus plus the large scale fleets — 1024 and 4096 nodes on random
//! 4-regular graphs and 10k nodes on the 100×100 torus — the
//! events-per-second figures the scale presets gate on. Reports into
//! the shared `BENCH_*.json` pipeline (including peak RSS); CI's
//! bench-smoke job fails if a fabric row drops below 1M events/s or
//! the process breaches its memory ceiling.
//!
//!   cargo bench --bench micro_simnet
//!   LMDFL_BENCH_QUICK=1 LMDFL_BENCH_JSON=bench-reports \
//!       cargo bench --bench micro_simnet    # CI smoke + JSON artifact

use lmdfl::bench::{black_box, Bencher};
use lmdfl::config::TopologyKind;
use lmdfl::simnet::{
    ComputeModel, EventQueue, Fabric, LinkModel, NetworkConfig,
};
use lmdfl::topology::Topology;

fn network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.002,
            bandwidth_bps: 5e6,
            jitter_s: 0.0005,
            drop_prob: 0.01,
        },
        link_hetero_spread: 0.5,
        compute: ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.5,
            straggler_prob: 0.1,
            straggler_slowdown: 4.0,
        },
        churn: Default::default(),
    }
}

fn main() {
    let mut b = Bencher::new();

    // raw queue: schedule + drain 4096 events per iteration
    const QN: u64 = 4096;
    b.run_elems("event queue schedule+pop x4096", QN, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..QN {
            // scatter times deterministically to exercise heap reordering
            q.schedule(i.wrapping_mul(0x9E37) % 100_000, i as u32);
        }
        let mut acc = 0u64;
        while let Some((t, p)) = q.pop() {
            acc = acc.wrapping_add(t).wrapping_add(p as u64);
        }
        black_box(acc);
    });

    // full fabric rounds: events/iteration is measured once, then used
    // as the throughput denominator for the timed runs. The large
    // fleets (1024 / 4096 random-regular, 10k torus) are the PR 8
    // scale gates: CI's bench-smoke job requires ≥1M events/s on these
    // rows and a bounded peak RSS in the JSON report.
    let sizes: &[(usize, TopologyKind, &str)] = &[
        (16, TopologyKind::Torus, "torus"),
        (64, TopologyKind::Torus, "torus"),
        (1024, TopologyKind::RandomRegular { k: 4 }, "random-regular"),
        (4096, TopologyKind::RandomRegular { k: 4 }, "random-regular"),
        (10_000, TopologyKind::Torus, "torus"),
    ];
    for &(nodes, ref kind, label) in sizes {
        let topo = Topology::build(kind, nodes, 0);
        let net = network();
        let bytes = vec![4096u64; nodes];

        let events_per_round = {
            let mut probe = Fabric::new(&net, &topo, 1);
            let before = probe.events_processed();
            probe.simulate_round(4, &bytes, &bytes);
            probe.events_processed() - before
        };

        let mut fabric = Fabric::new(&net, &topo, 1);
        b.run_elems(
            &format!("fabric round n={nodes} {label}"),
            events_per_round,
            || {
                black_box(fabric.simulate_round(4, &bytes, &bytes));
            },
        );
        println!(
            "n={nodes}: {events_per_round} events/round, digest {:#x}",
            fabric.event_digest()
        );
    }

    if let Some(rss) = lmdfl::bench::peak_rss_bytes() {
        println!("peak rss: {:.1} MiB", rss as f64 / (1 << 20) as f64);
    }
    b.finish("micro_simnet");
}
