//! Micro-bench: simnet event throughput.
//!
//! Measures (a) the raw event-queue schedule/pop rate and (b) full
//! fabric rounds (links + compute + stragglers) at 16 and 64 nodes on a
//! torus — the events-per-second figure every future scaling PR (async
//! gossip, sharded fleets) budgets against. Reports into the shared
//! `BENCH_*.json` pipeline; CI's bench-smoke job fails if the simnet
//! section goes missing.
//!
//!   cargo bench --bench micro_simnet
//!   LMDFL_BENCH_QUICK=1 LMDFL_BENCH_JSON=bench-reports \
//!       cargo bench --bench micro_simnet    # CI smoke + JSON artifact

use lmdfl::bench::{black_box, Bencher};
use lmdfl::config::TopologyKind;
use lmdfl::simnet::{
    ComputeModel, EventQueue, Fabric, LinkModel, NetworkConfig,
};
use lmdfl::topology::Topology;

fn network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.002,
            bandwidth_bps: 5e6,
            jitter_s: 0.0005,
            drop_prob: 0.01,
        },
        link_hetero_spread: 0.5,
        compute: ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.5,
            straggler_prob: 0.1,
            straggler_slowdown: 4.0,
        },
        churn: Default::default(),
    }
}

fn main() {
    let mut b = Bencher::new();

    // raw queue: schedule + drain 4096 events per iteration
    const QN: u64 = 4096;
    b.run_elems("event queue schedule+pop x4096", QN, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..QN {
            // scatter times deterministically to exercise heap reordering
            q.schedule(i.wrapping_mul(0x9E37) % 100_000, i as u32);
        }
        let mut acc = 0u64;
        while let Some((t, p)) = q.pop() {
            acc = acc.wrapping_add(t).wrapping_add(p as u64);
        }
        black_box(acc);
    });

    // full fabric rounds: events/iteration is measured once, then used
    // as the throughput denominator for the timed runs
    for &nodes in &[16usize, 64] {
        let topo = Topology::build(&TopologyKind::Torus, nodes, 0);
        let net = network();
        let bytes = vec![4096u64; nodes];

        let events_per_round = {
            let mut probe = Fabric::new(&net, &topo, 1);
            let before = probe.events_processed();
            probe.simulate_round(4, &bytes, &bytes);
            probe.events_processed() - before
        };

        let mut fabric = Fabric::new(&net, &topo, 1);
        b.run_elems(
            &format!("fabric round n={nodes} torus"),
            events_per_round,
            || {
                black_box(fabric.simulate_round(4, &bytes, &bytes));
            },
        );
        println!(
            "n={nodes}: {events_per_round} events/round, digest {:#x}",
            fabric.event_digest()
        );
    }

    b.finish("micro_simnet");
}
