//! Micro-bench: matrix-engine round throughput, sequential vs parallel.
//!
//! Runs the same LM-DFL round workload at 8 / 16 / 32 nodes with
//! `parallelism = off` and `parallelism = auto` and reports the speedup —
//! the acceptance number for the parallel zero-alloc round executor (the
//! two paths are bit-identical; see rust/tests/engine_parallel.rs).
//!
//!   cargo bench --bench micro_runtime
//!   LMDFL_BENCH_QUICK=1 LMDFL_BENCH_JSON=bench-reports \
//!       cargo bench --bench micro_runtime     # CI smoke + JSON artifact

use lmdfl::bench::{black_box, Bencher};
use lmdfl::config::{
    BackendKind, DatasetKind, ExperimentConfig, LrSchedule, Parallelism,
    QuantizerKind, TopologyKind,
};
use lmdfl::dfl::Trainer;

fn cfg(nodes: usize, parallelism: Parallelism) -> ExperimentConfig {
    ExperimentConfig {
        name: "micro_runtime".into(),
        seed: 3,
        nodes,
        tau: 4,
        rounds: 4,
        batch_size: 32,
        lr: LrSchedule::fixed(0.05),
        topology: TopologyKind::Ring,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 12 },
        dataset: DatasetKind::Blobs {
            train: 64 * nodes,
            test: 64,
            dim: 64,
            classes: 10,
        },
        backend: BackendKind::RustMlp { hidden: vec![128] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1_000_000, // exclude eval cost from the round timing
        parallelism,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

fn main() {
    let mut b = Bencher::new();
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("hardware threads: {hw}");

    for &nodes in &[8usize, 16, 32] {
        let mut seq =
            Trainer::build(&cfg(nodes, Parallelism::Off)).unwrap();
        let mut k = 0usize;
        let seq_mean = b
            .run(&format!("engine round n={nodes} parallelism=off"), || {
                black_box(seq.engine_mut().round(k).unwrap());
                k += 1;
            })
            .mean();

        let mut par =
            Trainer::build(&cfg(nodes, Parallelism::Auto)).unwrap();
        let workers = par.engine().workers();
        let mut k = 0usize;
        let par_mean = b
            .run(
                &format!(
                    "engine round n={nodes} parallelism=auto(w={workers})"
                ),
                || {
                    black_box(par.engine_mut().round(k).unwrap());
                    k += 1;
                },
            )
            .mean();

        println!(
            "n={nodes}: {:.2}x round-throughput speedup \
             (off {:.3}ms -> auto {:.3}ms, {workers} workers)",
            seq_mean / par_mean,
            seq_mean * 1e3,
            par_mean * 1e3,
        );
    }

    b.finish("micro_runtime");
}
