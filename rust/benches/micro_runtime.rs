//! Micro-bench: PJRT HLO executable dispatch — per-step latency of the AOT
//! model step vs the pure-Rust backend, and the LM-quantize HLO kernel vs
//! the native Rust quantizer (L1-vs-L3 comparison).
//!
//! Skips (cleanly) when artifacts/ is missing.
//!
//!   make artifacts && cargo bench --bench micro_runtime

use lmdfl::bench::{black_box, Bencher};
use lmdfl::dfl::backend::{LocalUpdate, RustMlpBackend};
use lmdfl::quant::{LloydMaxQuantizer, Quantizer};
use lmdfl::runtime::{
    artifacts_available, artifacts_dir, literal_f32, HloBackend,
    HloExecutor, Manifest,
};
use lmdfl::util::rng::Rng;

fn main() {
    if !artifacts_available() {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let dir = artifacts_dir();
    let mut b = Bencher::new();
    let mut rng = Rng::new(0);

    // ---- model step: HLO vs pure Rust ----------------------------------
    let mut hlo = HloBackend::load(&dir, "mlp_mnist", 784, 10).unwrap();
    let mut rust = RustMlpBackend::new(784, &[256, 128], 10);
    assert_eq!(hlo.param_count(), rust.param_count(),
        "manifest MLP dims drifted from the rust mirror");
    let mut params = hlo.init_params(&mut rng);
    let x: Vec<f32> =
        (0..32 * 784).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<u32> = (0..32).map(|_| rng.below(10) as u32).collect();

    b.run("hlo mlp_mnist step (B=32)", || {
        black_box(hlo.step(&mut params, &x, &y, 0.01).unwrap());
    });
    let mut params2 = params.clone();
    b.run("rust mlp step (B=32)", || {
        black_box(rust.step(&mut params2, &x, &y, 0.01).unwrap());
    });
    b.run("hlo mlp_mnist evaluate (B=32)", || {
        black_box(hlo.evaluate(&params, &x, &y).unwrap());
    });

    // ---- LM quantize: HLO Pallas kernel vs native Rust ------------------
    let manifest = Manifest::load(&dir).unwrap();
    if let Ok(info) = manifest.get("lm_quantize_s16") {
        let client = xla::PjRtClient::cpu().unwrap();
        let exe = HloExecutor::compile(&client, info.clone()).unwrap();
        let d = info.input("v").unwrap().elements();
        let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let bnd: Vec<f32> =
            (0..=16).map(|j| j as f32 / 16.0).collect();
        let lev: Vec<f32> =
            (0..16).map(|j| (j as f32 + 0.5) / 16.0).collect();
        let inputs = vec![
            literal_f32(&v, &[d]).unwrap(),
            literal_f32(&lev, &[16]).unwrap(),
            literal_f32(&bnd, &[17]).unwrap(),
        ];
        b.run_elems("hlo lm_quantize s=16 (pallas)", d as u64, || {
            black_box(exe.run(&inputs).unwrap());
        });
        let mut native = LloydMaxQuantizer::new(16, 12);
        b.run_elems("rust lm quantize s=16 (incl. fit)", d as u64, || {
            black_box(native.quantize(&v, &mut rng));
        });
    }
}
